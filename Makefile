# Tier-1 verify (ROADMAP.md) — run verbatim.
PYTHON ?= python

.PHONY: test test-slow bench-kernels bench-json bench-serving bench-smoke \
	lint ci

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q

# includes the slow-marked differential sweeps (500-schedule acceptance run
# and the >1k-op mutation schedules)
test-slow:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q --runslow

bench-kernels:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/kernel_bench.py

# perf trajectory across PRs: writes BENCH_kernels.json (probe + insert/grow)
bench-json:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/kernel_bench.py --json

# serving-engine throughput trajectory: coalesced ticks vs per-request
# baseline at 64 concurrent requests; APPENDS a run to BENCH_serving.json
bench-serving:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/serving_bench.py --json

# fast serving-bench smoke (no JSON write) for ci
bench-smoke:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/serving_bench.py --smoke

# ruff check (config in pyproject.toml); dependency-free fallback when the
# container has no ruff (no pip installs allowed)
lint:
	$(PYTHON) tools/lint.py

# the full gate: lint + tier-1 tests + a fast bench smoke
ci: lint test bench-smoke
