# Tier-1 verify (ROADMAP.md) — run verbatim.
PYTHON ?= python

.PHONY: test test-slow bench-kernels bench-json bench-serving \
	bench-serving-mesh bench-smoke fused-smoke fp-smoke trace-smoke \
	grow-smoke bench-check lint ci

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q

# includes the slow-marked differential sweeps (500-schedule acceptance run
# and the >1k-op mutation schedules)
test-slow:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q --runslow

bench-kernels:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/kernel_bench.py

# perf trajectory across PRs: writes BENCH_kernels.json (probe + insert/grow)
bench-json:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/kernel_bench.py --json

# serving-engine throughput trajectory: coalesced ticks vs per-request
# baseline at 64 concurrent requests, plus fused-vs-unfused mesh rows
# (launch count 3 -> 1, route_cap_* skew telemetry; the bench spawns a
# 2-forced-device child for them so the host rows keep the real device);
# APPENDS a run to BENCH_serving.json
bench-serving:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/serving_bench.py --json --mesh-shards 2

# serving bench with mesh-backed shards on 4 forced host devices (adds
# mesh / mesh_pipelined rows; no JSON append by default)
bench-serving-mesh:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/serving_bench.py --mesh-shards 4

# fast serving-bench smoke (no JSON write) for ci
bench-smoke:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/serving_bench.py --smoke

# fast fused-vs-unfused differential smoke on 2 forced host devices: a few
# mixed schedules bit-compared fused vs three-call vs host reference, plus
# the adversarial worst-skew capacity check (tests/sharded_driver.py)
fused-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	PYTHONPATH=src:tests$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -c "from sharded_driver import fused_smoke; fused_smoke()"

# fingerprint-ablation smoke: mixed insert/probe/delete/grow churn must be
# bit-equal with fingerprints on vs off (pure filter) and match the
# DictModel oracle, over (plain, displaced+stash) x (ref, perf)
fp-smoke:
	PYTHONPATH=src:tests$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -c "from fp_ablation import fp_smoke; fp_smoke()"

# observability smoke: traced YCSB-A kv run on 2 forced host devices with
# pipeline depth 2 (fused mesh megakernel path), Perfetto export +
# Prometheus exposition, then trace_report validates the event stream
# (B/E balance, per-track monotonic ts) and asserts the documented span
# vocabulary and at least one write-claim pipeline stall
trace-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -m repro.launch.serve --mode kv --workloads A \
	    --requests 48 --slots 16 --record-count 512 \
	    --mesh-shards 2 --pipeline 2 \
	    --trace-out /tmp/hashmem_trace.json \
	    --metrics-prom /tmp/hashmem_metrics.prom > /dev/null
	$(PYTHON) tools/trace_report.py /tmp/hashmem_trace.json \
	    --assert-spans tick,gather,route,fused_tick,writeback,admit,preload \
	    --assert-stalls 1

# extendible-resize smoke: insert-heavy pipelined (depth 2) mesh run on 2
# forced host devices that forces >= 2 group splits mid-pipeline, bit-
# compared against the host reference and the DictModel replay
# (tests/sharded_driver.py grow_smoke); trace_report then asserts the
# repairs traced as "split" spans and NO "grow" (rebuild) span occurred —
# an extendible split must repair inline without a stop-the-world rebuild
grow-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	PYTHONPATH=src:tests$${PYTHONPATH:+:$$PYTHONPATH} \
	$(PYTHON) -c "from sharded_driver import grow_smoke; \
	    grow_smoke('/tmp/hashmem_grow_trace.json')"
	$(PYTHON) tools/trace_report.py /tmp/hashmem_grow_trace.json \
	    --assert-spans tick,split,fused_tick,writeback \
	    --forbid-spans grow

# perf-trajectory regression guard: newest BENCH_*.json run vs the best of
# the last 5 prior runs, >1.5x fails (noisy eager metrics get a 2x band;
# first-appearance metrics warn; tools/bench_check.py)
bench-check:
	$(PYTHON) tools/bench_check.py

# ruff check (config in pyproject.toml); dependency-free fallback when the
# container has no ruff (no pip installs allowed)
lint:
	$(PYTHON) tools/lint.py

# the full gate: lint + tier-1 tests + bench smoke + fused differential
# smoke + fingerprint ablation + traced-run smoke + extendible-resize
# smoke + perf guard
ci: lint test bench-smoke fused-smoke fp-smoke trace-smoke grow-smoke \
	bench-check
