# Tier-1 verify (ROADMAP.md) — run verbatim.
PYTHON ?= python

.PHONY: test test-slow bench-kernels bench-json lint

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q

# includes the slow-marked differential sweeps (500-schedule acceptance run
# and the >1k-op mutation schedules)
test-slow:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) -m pytest -x -q --runslow

bench-kernels:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/kernel_bench.py

# perf trajectory across PRs: writes BENCH_kernels.json (probe + insert/grow)
bench-json:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PYTHON) benchmarks/kernel_bench.py --json

# ruff check (config in pyproject.toml); dependency-free fallback when the
# container has no ruff (no pip installs allowed)
lint:
	$(PYTHON) tools/lint.py
