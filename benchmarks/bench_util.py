"""Shared helpers for the BENCH_*.json perf trajectories."""
from __future__ import annotations

import json
import os


def append_run(path: str, payload: dict) -> int:
    """Append this run to the trajectory file ({"bench", "runs": [...]}),
    migrating the legacy single-run {"bench", "rows"} layout in place.
    Returns the run count after appending."""
    doc = {"bench": payload.get("bench", ""), "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        if "runs" in old:
            doc = old
        elif "rows" in old:                      # legacy single-run layout
            doc["bench"] = old.get("bench", doc["bench"])
            doc["runs"] = [{"rows": old["rows"]}]
    doc["runs"].append({k: v for k, v in payload.items() if k != "bench"})
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return len(doc["runs"])
