"""Paper Fig. 4: bucket-length distribution after mapping dictionary words.

The paper hashes the first 350k words of a dictionary and observes large
variance in bucket lengths (under-/over-utilized buckets, §2.5).  We
dictionary-encode synthetic words (data/kv_synth.dictionary_words) exactly
as §4.1.1 prescribes for string data and reproduce the histogram statistics
for both the paper's default-style hash and the murmur3 finisher the paper's
§6 'Hash Function' future-work calls for.
"""
from __future__ import annotations


from repro.configs.base import HashMemConfig
from repro.core import hashmap


def run(n_words: int = 50_000, num_buckets: int = 4096, slots: int = 64):
    from repro.data.kv_synth import dictionary_words
    words = dictionary_words(n_words)
    rows = []
    for fn in ("mult_shift", "murmur3_fmix"):
        cfg = HashMemConfig(num_buckets=num_buckets, slots_per_page=slots,
                            overflow_pages=num_buckets, hash_fn=fn,
                            max_chain=8, backend="ref")
        chk = hashmap.build_check(cfg, words)
        counts = chk["bucket_counts"]
        mean = counts.mean()
        rows.append({
            "name": f"fig4_buckets_{fn}",
            "mean_len": float(mean),
            "std_len": float(counts.std()),
            "max_len": int(counts.max()),
            "cv": float(counts.std() / mean),
            "frac_under_half": float((counts < 0.5 * mean).mean()),
            "frac_over_2x": float((counts > 2 * mean).mean()),
            "overflow_pages_needed": chk["overflow_pages_needed"],
            "max_chain_needed": chk["max_chain_needed"],
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
