"""Paper Fig. 5: probing times of CPU data structures (measured here).

Baselines (in-process stand-ins for the paper's C++ trio):
  dict            — CPython dict = chained hash table (std::unordered_map)
  sorted_binsearch— np.searchsorted over a sorted array: the balanced-BST
                    (std::map) probe structure, O(log n) random touches
  open_addressing — NumPy linear-probing table (vectorized)
  hopscotch       — NumPy hopscotch map, neighborhood H=32 (Herlihy et al.),
                    the paper's tsl::hopscotch_map analogue

Each returns measured µs/probe at the configured scale (default 2^20 pairs —
out-of-cache on this container; --full restores the paper's 100M where RAM
permits the numpy structures).
"""
from __future__ import annotations

import time

import numpy as np

from repro.data.kv_synth import kv_dataset, probe_set

H = 32  # hopscotch neighborhood


def build_open_addressing(keys, vals, load=0.5):
    size = 1 << int(np.ceil(np.log2(len(keys) / load)))
    table_k = np.full(size, 0xFFFFFFFF, np.uint32)
    table_v = np.zeros(size, np.uint32)
    idx = (keys.astype(np.uint64) * 2654435761 % size).astype(np.int64)
    pending = np.arange(len(keys))
    pos = idx.copy()
    while pending.size:
        free = table_k[pos[pending]] == 0xFFFFFFFF
        take = pending[free]
        # unique positions only this round
        p, first = np.unique(pos[take], return_index=True)
        take = take[first]
        table_k[pos[take]] = keys[take]
        table_v[pos[take]] = vals[take]
        done = np.zeros(len(keys), bool)
        done[take] = True
        pending = pending[~done[pending]]
        pos[pending] = (pos[pending] + 1) % size
    return table_k, table_v, size


def probe_open_addressing(table_k, table_v, size, queries, max_steps=64):
    pos = (queries.astype(np.uint64) * 2654435761 % size).astype(np.int64)
    out = np.zeros(len(queries), np.uint32)
    found = np.zeros(len(queries), bool)
    live = np.arange(len(queries))
    for _ in range(max_steps):
        k = table_k[pos[live]]
        hit = k == queries[live]
        out[live[hit]] = table_v[pos[live[hit]]]
        found[live[hit]] = True
        empty = k == 0xFFFFFFFF
        live = live[~(hit | empty)]
        if not live.size:
            break
        pos[live] = (pos[live] + 1) % size
    return out, found


def build_hopscotch(keys, vals, load=0.5):
    """Hopscotch: every key within H-1 of its home bucket."""
    size = 1 << int(np.ceil(np.log2(len(keys) / load)))
    tk = np.full(size + H, 0xFFFFFFFF, np.uint32)
    tv = np.zeros(size + H, np.uint32)
    home = (keys.astype(np.uint64) * 2654435761 % size).astype(np.int64)
    order = np.argsort(home)
    for i in order:                      # insertion is host-side, probe is hot
        h = home[i]
        placed = False
        for d in range(H):
            if tk[h + d] == 0xFFFFFFFF:
                tk[h + d] = keys[i]
                tv[h + d] = vals[i]
                placed = True
                break
        if not placed:
            raise RuntimeError("hopscotch displacement needed; lower load")
    return tk, tv, size


def probe_hopscotch(tk, tv, size, queries):
    home = (queries.astype(np.uint64) * 2654435761 % size).astype(np.int64)
    out = np.zeros(len(queries), np.uint32)
    found = np.zeros(len(queries), bool)
    for d in range(H):                   # H vectorized neighborhood checks
        k = tk[home + d]
        hit = (k == queries) & ~found
        out[hit] = tv[home[hit] + d]
        found |= hit
    return out, found


def run(n: int = 1 << 20, probe_frac: float = 0.1, repeats: int = 3):
    keys, vals = kv_dataset(n, seed=0)
    q, idx = probe_set(keys, probe_frac)
    rows = []

    def timeit(fn, *args):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn(*args)
            best = min(best, time.perf_counter() - t0)
        return best, out

    # dict (unordered_map analogue)
    d = {int(k): int(v) for k, v in zip(keys, vals)}
    ql = [int(x) for x in q]
    t, out = timeit(lambda: [d[k] for k in ql])
    assert out == [int(v) for v in vals[idx]]
    rows.append({"name": "fig5_dict", "us_per_probe": t / len(q) * 1e6})

    # sorted array binary search (std::map probe-structure analogue)
    order = np.argsort(keys)
    sk, sv = keys[order], vals[order]
    t, pos = timeit(np.searchsorted, sk, q)
    assert (sk[pos] == q).all()
    rows.append({"name": "fig5_sorted_binsearch",
                 "us_per_probe": t / len(q) * 1e6})

    # open addressing
    tk, tv, size = build_open_addressing(keys, vals)
    t, (out, found) = timeit(probe_open_addressing, tk, tv, size, q)
    assert found.all() and (out == vals[idx]).all()
    rows.append({"name": "fig5_open_addressing",
                 "us_per_probe": t / len(q) * 1e6})

    # hopscotch
    tk, tv, size = build_hopscotch(keys, vals)
    t, (out, found) = timeit(probe_hopscotch, tk, tv, size, q)
    assert found.all() and (out == vals[idx]).all()
    rows.append({"name": "fig5_hopscotch", "us_per_probe": t / len(q) * 1e6})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
