"""Paper Fig. 6: HashMem speedups vs CPU baselines.

Validation logic (EXPERIMENTS.md §Paper-validation): the paper publishes six
speedups (area/perf x map/unordered/hopscotch) but no absolute times.  Our
DDR4 model fixes the subarray latencies from JEDEC timings:

    t_scan(area) = tRCD + 381 * tCCD_S + latch   (avg occupancy of the
                    100M-pair workload over 2^18 buckets = 381 slots)
    t_cam(perf)  = tRCD + 2 ticks + latch

One calibrated variant-independent overhead (T_OVERHEAD_NS = 470 ns, the MC
command + translation + LLC delivery path) then makes ALL SIX paper numbers
mutually consistent: the CPU times implied by the area column equal the CPU
times implied by the perf column to <0.5%.  That rank-1 consistency is the
reproduction check; this module computes it, plus:

  * measured-CPU speedups on this container (fig5 structures),
  * beyond-paper overlapped-probe throughput (tFAW/channel bound analysis)
    and the §6 channel-parallelism scaling the paper lists as future work.
"""
from __future__ import annotations


from benchmarks import timing_model as tm
from benchmarks.fig5_cpu_baselines import run as fig5_run

PAPER_SPEEDUPS = {
    "std_map": {"area": 17.1, "perf": 49.1},
    "unordered_map": {"area": 5.5, "perf": 15.8},
    "hopscotch_map": {"area": 3.2, "perf": 9.2},
}

# paper workload geometry: 100M pairs over 2^18 buckets x 512 slots
PAPER_AVG_OCCUPANCY = 100_000_000 / (1 << 18)     # ~381 live slots per row


def run(measured_cpu=None):
    rows = []
    lat = {v: tm.hashmem_latency_ns(v, PAPER_AVG_OCCUPANCY)
           for v in ("area", "perf", "bitserial")}
    for v, t in lat.items():
        rows.append({"name": f"fig6_latency_{v}", "t_ns": round(t, 1)})

    # --- paper-consistency reproduction ---
    for base, sp in PAPER_SPEEDUPS.items():
        cpu_from_area = sp["area"] * lat["area"]
        cpu_from_perf = sp["perf"] * lat["perf"]
        err = abs(cpu_from_area - cpu_from_perf) / cpu_from_perf
        implied = 0.5 * (cpu_from_area + cpu_from_perf)
        rows.append({
            "name": f"fig6_implied_cpu_{base}",
            "implied_cpu_ns": round(implied, 0),
            "consistency_err": round(err, 4),
            "repro_area_x": round(implied / lat["area"], 1),
            "paper_area_x": sp["area"],
            "repro_perf_x": round(implied / lat["perf"], 1),
            "paper_perf_x": sp["perf"],
        })

    # --- measured-CPU speedups (this container) ---
    measured = measured_cpu or fig5_run(n=1 << 20)
    for m in measured:
        r = {"name": f"fig6_measured_{m['name'].replace('fig5_', '')}"}
        for v in ("area", "perf"):
            r[f"speedup_{v}"] = round(m["us_per_probe"] * 1e3 / lat[v], 1)
        rows.append(r)

    # --- beyond-paper: overlapped throughput + channel scaling (§6) ---
    for v in ("area", "perf", "bitserial"):
        t = tm.hashmem_throughput(v, PAPER_AVG_OCCUPANCY)
        rows.append({"name": f"fig6_overlapped_{v}",
                     "rate_mps": round(t["rate_mps"], 1),
                     "ns_per_probe": round(t["ns_per_probe"], 2),
                     "bound": t["bound"]})
    for ch in (1, 2, 4, 8):
        t = tm.hashmem_throughput("perf", PAPER_AVG_OCCUPANCY, channels=ch)
        rows.append({"name": f"fig6_channels_{ch}",
                     "rate_mps": round(t["rate_mps"], 1),
                     "bound": t["bound"]})

    # --- bit-serial crossover (paper column widths; DESIGN.md §2) ---
    for bits in (4, 8, 16, 32):
        t = tm.hashmem_latency_ns("bitserial", PAPER_AVG_OCCUPANCY,
                                  key_bits=bits)
        rows.append({"name": f"fig6_bitserial_{bits}b",
                     "t_ns": round(t, 1),
                     "vs_perf": round(t / lat["perf"], 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
