"""TPU probe-kernel analysis: VMEM footprints (the paper-§4.3 'area overhead'
analogue on TPU) + interpret-mode correctness throughput on CPU.

On-TPU wall-clock is not available in this container; the structural numbers
(bytes of BlockSpec tiles per grid step, vector ops per probe) come from the
kernel definitions and are the quantities a Mosaic schedule would be built
around (see EXPERIMENTS.md §Perf).

``--json`` APPENDS this run to ``BENCH_kernels.json`` (see ``make
bench-json``) so per-backend probe and insert/grow timings are tracked as a
per-PR trajectory (a ``runs`` list; one entry per ``make bench-json``).
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from bench_util import append_run
from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.core.introspect import count_scatters

VMEM_BYTES = 128 * 1024 * 1024  # v5e VMEM per core


def vmem_footprint(slots: int, key_bits: int = 32):
    """Bytes resident per grid step for each kernel variant.

    perf/area fetch ONE interleaved (slots, 2) row per chain step — the
    unified PageStore activation carrying keys and values together;
    bitserial's BlockSpec selects only the pool's value lane (its keys live
    in the plane row)."""
    row_kv = slots * 2 * 4                # uint32 interleaved key/value row
    val_lane = slots * 4                  # (1, S, 1) value-lane block
    line = 128 * 4
    planes = key_bits * (slots // 32) * 4
    return {
        "perf": row_kv + line,
        "area": row_kv + line,
        "bitserial": planes + val_lane + line,
    }


def _bench(fn, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-iters wall time of a blocking thunk (compile excluded)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _median(fn, iters: int = 7) -> float:
    """Median wall time of a blocking thunk (first call = warmup/compile)."""
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def insert_bench(batches=(4096, 16384), slots: int = 256):
    """Vectorized batch insert vs the seed's sequential lax.scan insert.

    Two comparisons, same calling convention on both sides:
      * eager — how the serving stack (PageTableManager) actually calls the
        mutation path, and the only way the seed ever ran it.  This is the
        headline ``speedup_vs_seed`` (acceptance bar: >=5x at batch >= 4096
        on CPU — the scan dispatches the whole batch serially, the
        vectorized path is one sort + a handful of scatters).
      * jitted — both compiled, isolates the algorithmic win from dispatch
        overhead (smaller ratio: XLA-CPU scatter cost per element is the
        shared floor).

    Each row also reports ``scatters_per_insert``, the pool-scatter count
    traced from the insert jaxpr: the unified PageStore's fused key/value
    row write brings it from the split layout's 5 down to 3.
    """
    import jax

    rows = []
    cfg = HashMemConfig(num_buckets=2048, slots_per_page=slots,
                        overflow_pages=2048, max_chain=8, backend="perf")
    jit_vec = jax.jit(hashmap.insert)
    jit_scan = jax.jit(hashmap.insert_scan)
    rng = np.random.default_rng(0)
    hm = hashmap.create(cfg)
    for B in batches:
        keys = jnp.asarray(
            rng.choice(2**31, B, replace=False).astype(np.uint32))
        vals = keys * jnp.uint32(3)

        def blocked(fn):
            return lambda: jax.block_until_ready(
                fn(hm, keys, vals)[0].store.pool)

        t_vec = _median(blocked(hashmap.insert))
        t_scan = _median(blocked(hashmap.insert_scan))
        tj_vec = _median(blocked(jit_vec))
        tj_scan = _median(blocked(jit_scan))
        rows.append({"name": f"insert_batch{B}",
                     "scatters_per_insert": count_scatters(hashmap.insert,
                                                           hm, keys, vals),
                     "vec_us_per_elem": t_vec / B * 1e6,
                     "scan_us_per_elem": t_scan / B * 1e6,
                     "speedup_vs_seed": t_scan / t_vec,
                     "jit_vec_us_per_elem": tj_vec / B * 1e6,
                     "jit_scan_us_per_elem": tj_scan / B * 1e6,
                     "speedup_jit": tj_scan / tj_vec})
    return rows


def grow_bench(sizes=(1024, 4096), slots: int = 256):
    """Cost of a full grow() rehash (doubling) at ~60% load."""
    import jax

    rows = []
    rng = np.random.default_rng(1)
    for nb in sizes:
        cfg = HashMemConfig(num_buckets=nb, slots_per_page=slots,
                            overflow_pages=nb, max_chain=8, backend="perf")
        n = int(0.6 * nb * slots)
        keys = jnp.asarray(rng.choice(2**31, n, replace=False).astype(np.uint32))
        hm = hashmap.build(cfg, keys, keys)
        g = jax.jit(hashmap.grow)
        t = _bench(lambda: jax.block_until_ready(g(hm)))
        rows.append({"name": f"grow_{nb}x{slots}",
                     "entries": n,
                     "grow_ms": t * 1e3,
                     "ns_per_live_entry": t / n * 1e9})
    return rows


def run(slots: int = 512, Q: int = 256):
    rows = []
    fp = vmem_footprint(slots)
    for v, b in fp.items():
        rows.append({"name": f"kernel_vmem_{v}", "bytes_per_step": b,
                     "frac_of_vmem": b / VMEM_BYTES,
                     "vector_ops_per_probe":
                         {"perf": 2, "area": slots // 128, "bitserial": 32 + 3}[v]})
    # interpret-mode throughput (correctness-path timing only)
    rng = np.random.default_rng(0)
    n = 64 * slots // 2
    keys = rng.choice(2**31, n, replace=False).astype(np.uint32)
    q = jnp.asarray(keys[:Q])
    for backend in ("ref", "perf", "area", "bitserial"):
        hm2 = hashmap.build(
            HashMemConfig(num_buckets=64, slots_per_page=slots,
                          overflow_pages=64, max_chain=2, backend=backend),
            jnp.asarray(keys), jnp.asarray(keys))
        vfn = lambda: hashmap.probe(hm2, q)[0].block_until_ready()
        # min-of-5 (warmup excludes compile): single-shot wall times were
        # the noisiest rows in the BENCH_kernels.json trajectory
        dt = _bench(vfn, warmup=1, iters=5)
        rows.append({"name": f"kernel_interpret_{backend}",
                     "us_per_probe": dt / Q * 1e6})
    return rows


def zipfian_rows_bench(theta: float = 0.99, Q: int = 2048,
                       rounds: int = 6, per_round: int = 2048):
    """YCSB-zipfian ``rows_activated_per_probe``, fingerprints on vs off.

    Builds a displaced+fingerprinted table through insert/delete churn —
    tombstoned slots accumulate mid-chain, so a fingerprint-blind probe
    keeps activating pages whose keys can no longer match — then probes a
    zipfian(theta) query batch over the live keys and reports the traced
    mean row activations both ways (hashmap.rows_activated_per_probe).
    The fp row is the headline: the paper's ~1 row per probe."""
    import jax

    cfg = HashMemConfig(num_buckets=64, slots_per_page=128,
                        overflow_pages=256, max_chain=8, backend="ref",
                        displacement=True, fingerprint_bits=12,
                        stash_slots=256, auto_grow=False)
    rng = np.random.default_rng(7)
    allk = rng.choice(2**31, rounds * per_round, replace=False) \
        .astype(np.uint32)
    hm = hashmap.create(cfg)
    live: list = []
    for r in range(rounds):
        ks = allk[r * per_round:(r + 1) * per_round]
        hm, ok = hashmap.insert(hm, jnp.asarray(ks), jnp.asarray(ks * 3))
        live.extend(int(k) for k in ks[np.asarray(ok)])
        dead = rng.choice(len(live), len(live) // 3, replace=False)
        dk = np.asarray(live, np.uint32)[dead]
        hm, _ = hashmap.delete(hm, jnp.asarray(dk))
        gone = set(int(k) for k in dk)     # keys are unique: one copy each
        live = [k for k in live if k not in gone]
    live_arr = np.asarray(live, np.uint32)
    w = 1.0 / np.arange(1, len(live_arr) + 1, dtype=np.float64) ** theta
    q = jnp.asarray(rng.choice(live_arr, Q, p=w / w.sum()))
    ra_fp = float(hashmap.rows_activated_per_probe(hm, q))
    ra_nofp = float(hashmap.rows_activated_per_probe(
        hm, q, use_fingerprints=False))
    st = hashmap.stats(hm)
    return [{"name": "kernel_zipfian_rows_activated",
             "rows_activated_per_probe_fp": ra_fp,
             "rows_activated_per_probe_nofp": ra_nofp,
             "fp_bits": cfg.fingerprint_bits,
             "stash_slots": cfg.stash_slots,
             "stash_live": int(st["stash_live"]),
             "zipf_theta": theta,
             "live_keys": int(len(live_arr))}]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write all rows to BENCH_kernels.json "
                         "(perf trajectory tracked across PRs)")
    ap.add_argument("--out", default=None,
                    help="JSON output path (implies --json); "
                         "default BENCH_kernels.json")
    args = ap.parse_args()
    if args.out is not None:
        args.json = True
    args.out = args.out or "BENCH_kernels.json"

    rows = run() + zipfian_rows_bench() + insert_bench() + grow_bench()
    for r in rows:
        print(r)
    if args.json:
        n = append_run(args.out, {"bench": "kernels", "rows": rows})
        print(f"appended run #{n} ({len(rows)} rows) -> {args.out}")


if __name__ == "__main__":
    main()
