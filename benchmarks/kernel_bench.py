"""TPU probe-kernel analysis: VMEM footprints (the paper-§4.3 'area overhead'
analogue on TPU) + interpret-mode correctness throughput on CPU.

On-TPU wall-clock is not available in this container; the structural numbers
(bytes of BlockSpec tiles per grid step, vector ops per probe) come from the
kernel definitions and are the quantities a Mosaic schedule would be built
around (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HashMemConfig
from repro.core import hashmap

VMEM_BYTES = 128 * 1024 * 1024  # v5e VMEM per core


def vmem_footprint(slots: int, key_bits: int = 32):
    """Bytes resident per grid step for each kernel variant."""
    row = slots * 4                       # uint32 keys
    vals = slots * 4
    line = 128 * 4
    planes = key_bits * (slots // 32) * 4
    return {
        "perf": row + vals + line,
        "area": row + vals + line,
        "bitserial": planes + vals + line,
    }


def run(slots: int = 512, Q: int = 256):
    rows = []
    fp = vmem_footprint(slots)
    for v, b in fp.items():
        rows.append({"name": f"kernel_vmem_{v}", "bytes_per_step": b,
                     "frac_of_vmem": b / VMEM_BYTES,
                     "vector_ops_per_probe":
                         {"perf": 2, "area": slots // 128, "bitserial": 32 + 3}[v]})
    # interpret-mode throughput (correctness-path timing only)
    cfg = HashMemConfig(num_buckets=64, slots_per_page=slots,
                        overflow_pages=64, max_chain=2, backend="ref")
    rng = np.random.default_rng(0)
    n = 64 * slots // 2
    keys = rng.choice(2**31, n, replace=False).astype(np.uint32)
    hm = hashmap.build(cfg, jnp.asarray(keys), jnp.asarray(keys))
    q = jnp.asarray(keys[:Q])
    for backend in ("ref", "perf", "area", "bitserial"):
        hm2 = hashmap.build(
            HashMemConfig(num_buckets=64, slots_per_page=slots,
                          overflow_pages=64, max_chain=2, backend=backend),
            jnp.asarray(keys), jnp.asarray(keys))
        vfn = lambda: hashmap.probe(hm2, q)[0].block_until_ready()
        vfn()  # compile
        t0 = time.perf_counter()
        vfn()
        dt = time.perf_counter() - t0
        rows.append({"name": f"kernel_interpret_{backend}",
                     "us_per_probe": dt / Q * 1e6})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
