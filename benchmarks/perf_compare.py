"""§Perf before/after: baseline artifacts vs REPRO_OPT artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import assemble_cell

GB = 2**30


def _full(art, arch, shape):
    p = Path(art) / f"{arch}__{shape}__single__full.json"
    return json.loads(p.read_text()) if p.exists() else None


def compare(cells, base="artifacts/dryrun_baseline", opt="artifacts/dryrun_opt"):
    rows = []
    for arch, shape in cells:
        b = _full(base, arch, shape)
        o = _full(opt, arch, shape)
        rb = assemble_cell(Path(base), arch, shape)
        ro = assemble_cell(Path(opt), arch, shape)
        if not (b and o):
            continue
        rows.append({
            "cell": f"{arch} x {shape}",
            "temp_gb": (b.get("temp_size_in_bytes", 0) / GB,
                        o.get("temp_size_in_bytes", 0) / GB),
            "args_gb": (b.get("argument_size_in_bytes", 0) / GB,
                        o.get("argument_size_in_bytes", 0) / GB),
            "coll_full_gb": (
                b.get("collectives", {}).get("total_bytes", 0) / GB,
                o.get("collectives", {}).get("total_bytes", 0) / GB),
            "coll_total_dev": (rb.get("coll_bytes_dev"), ro.get("coll_bytes_dev")),
            "flops_dev": (rb.get("flops_dev"), ro.get("flops_dev")),
            "bound": (rb.get("dominant"), ro.get("dominant")),
            "bound_s": (rb.get("bound_s"), ro.get("bound_s")),
            "roofline_frac": (rb.get("roofline_frac"), ro.get("roofline_frac")),
            "fits": (rb.get("fits_16g"), ro.get("fits_16g")),
        })
    return rows


def markdown(rows):
    out = ["| cell | temp GB | args GB | coll GB (dev) | dominant | bound s | roofline frac | fits 16G |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        def pair(t, fmt="{:.2f}"):
            a, b = t
            fa = fmt.format(a) if isinstance(a, (int, float)) and a is not None else "—"
            fb = fmt.format(b) if isinstance(b, (int, float)) and b is not None else "—"
            return f"{fa} → {fb}"
        out.append(
            f"| {r['cell']} | {pair(r['temp_gb'])} | {pair(r['args_gb'])} | "
            f"{pair(tuple((x or 0)/GB for x in r['coll_total_dev']), '{:.2f}')} | "
            f"{r['bound'][0]} → {r['bound'][1]} | "
            f"{pair(r['bound_s'], '{:.3g}')} | "
            f"{pair(r['roofline_frac'], '{:.3f}')} | "
            f"{r['fits'][0]} → {r['fits'][1]} |")
    return "\n".join(out)


if __name__ == "__main__":
    cells = [
        ("olmoe-1b-7b", "train_4k"), ("olmoe-1b-7b", "prefill_32k"),
        ("llama4-maverick-400b-a17b", "train_4k"),
        ("llama4-maverick-400b-a17b", "prefill_32k"),
        ("jamba-v0.1-52b", "train_4k"),
        ("llama3-8b", "decode_32k"), ("qwen3-8b", "decode_32k"),
        ("phi4-mini-3.8b", "decode_32k"), ("internvl2-2b", "decode_32k"),
        ("olmoe-1b-7b", "decode_32k"),
        ("llama4-maverick-400b-a17b", "decode_32k"),
        ("jamba-v0.1-52b", "decode_32k"), ("jamba-v0.1-52b", "long_500k"),
        ("h2o-danube-1.8b", "long_500k"),
        ("xlstm-1.3b", "train_4k"), ("whisper-tiny", "train_4k"),
    ]
    print(markdown(compare(cells)))
