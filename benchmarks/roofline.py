"""Roofline assembly from dry-run artifacts (EXPERIMENTS.md §Roofline).

Method (verified empirically, see dryrun.py docstring):
  * XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, so the
    production (scanned) lowering under-reports FLOPs/bytes/collectives.
  * The unit1/unit2 cost probes lower UNROLLED 1- and 2-unit models on the
    same mesh with the same shardings; depth-linear extrapolation
        cost(L) = c1 + (n_units - 1) * (c2 - c1)
    is exact for layer-homogeneous stacks (all assigned archs).
  * sLSTM time-recurrence (xlstm) stays a lax.scan even in probes (unrolling
    4096 steps is infeasible); its per-step cell cost is added analytically:
    cell flops = mult * 2 * 4 * B_loc * H * dh^2 per step, mult = 4 for
    training (fwd + remat-fwd + 2x bwd), 1 for prefill.

Roofline terms per (arch x shape), single-pod mesh (256 chips):
  compute    = HLO_flops_per_device / 197e12        [s]
  memory     = HLO_bytes_per_device / 819e9         [s]
  collective = collective_bytes_per_device / 50e9   [s]

MODEL_FLOPS = 6 * N (dense) or 6 * N_active (MoE) per token;
useful-fraction = model-flops time / max(term) — the §Perf score.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link (ICI)
CHIPS = 256              # single-pod roofline mesh

_param_cache: dict = {}


def _counts(arch):
    if arch not in _param_cache:
        from repro.configs import get_config
        from repro.models import model
        cfg = get_config(arch)
        _param_cache[arch] = (model.count_params(cfg),
                              model.count_params(cfg, active_only=True), cfg)
    return _param_cache[arch]


def _load(art_dir: Path, arch, shape, mesh, probe):
    p = art_dir / f"{arch}__{shape}__{mesh}__{probe}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("ok") else None


def _xlstm_correction(cfg, shape, n_units):
    """Per-device flops missing from probes for xlstm time/chunk scans.

    Probes keep the sLSTM time-scan AND the mLSTM chunk-scan as lax.scan
    (unrolled bwd is intractable to compile), so HloCostAnalysis counts each
    body once; the remaining (steps-1) bodies are added analytically:
      sLSTM step: 4-gate block-diag recurrent matmul  2*4*B*H*dh^2
      mLSTM chunk: intra-chunk ~4*B*H*L^2*dh + state path ~4*B*H*L*dh^2
    mult = 4 for training (fwd + remat-fwd + 2x bwd), 1 for prefill.
    """
    if cfg.family != "ssm" or shape.kind == "decode":
        return 0.0
    S = shape.seq_len
    B_loc = max(shape.global_batch // 16, 1)      # batch over 'data'=16
    H, dh = cfg.num_heads, cfg.head_dim
    mult = 4.0 if shape.kind == "train" else 1.0
    corr = 0.0
    if cfg.slstm_every:
        per_step = 2 * 4 * B_loc * H * dh * dh
        corr += mult * per_step * (S - 1) * n_units   # one sLSTM per unit
    L = cfg.mlstm_chunk
    nc = S // L
    body = B_loc * H * (4 * L * L * dh + 4 * L * dh * dh)
    n_mlstm = cfg.num_layers - (n_units if cfg.slstm_every else 0)
    corr += mult * body * (nc - 1) * n_mlstm
    return corr


def assemble_cell(art_dir: Path, arch: str, shape_name: str):
    from repro.configs import SHAPES
    from repro.models.transformer import scan_unit_size

    total_p, active_p, cfg = _counts(arch)
    shape = SHAPES[shape_name]
    unit = scan_unit_size(cfg)
    n_units = cfg.num_layers // unit

    full = _load(art_dir, arch, shape_name, "single", "full")
    c1 = _load(art_dir, arch, shape_name, "single", "unit1")
    c2 = _load(art_dir, arch, shape_name, "single", "unit2")
    multi = _load(art_dir, arch, shape_name, "multi", "full")
    if not full:
        return {"arch": arch, "shape": shape_name, "ok": False}

    def extrap(key, sub=None):
        if not c1:
            return None
        g1 = c1[sub][key] if sub else c1[key]
        if c2:
            g2 = c2[sub][key] if sub else c2[key]
            return g1 + (n_units - 1) * (g2 - g1)
        # unit2 probe unavailable (intractable unrolled compile, e.g. jamba):
        # estimate the depth-independent base analytically from the LM-head
        # CE path (mult 4.0 calibrated on llama3's unit1/unit2 pair: fwd +
        # checkpoint-recompute + 2x bwd) and extrapolate from unit1 alone.
        if shape.kind == "train":
            mult = 4.0
        elif shape.kind == "prefill":
            mult = 1.0
        else:
            mult = 1.0
        tokens_ = (shape.global_batch * shape.seq_len
                   if shape.kind != "decode" else shape.global_batch)
        base_flops = mult * 2 * cfg.d_model * cfg.padded_vocab * tokens_ / CHIPS
        if key == "flops_per_device":
            base = base_flops
        elif key == "bytes_per_device":
            base = base_flops / 120.0   # llama3-calibrated flops:bytes of base
        else:
            base = 0.0                  # head path is collective-light
        per_unit = max(g1 - base, 0.0)
        return base + n_units * per_unit

    flops = extrap("flops_per_device")
    mem_bytes = extrap("bytes_per_device")
    coll = extrap("total_bytes", "collectives")
    if flops is not None:
        flops += _xlstm_correction(cfg, shape, n_units)

    rec = {
        "arch": arch, "shape": shape_name, "ok": True,
        "n_units": n_units,
        "params_b": total_p / 1e9, "active_params_b": active_p / 1e9,
        "fits_16g": None, "multi_pod_ok": bool(multi),
        "flops_dev": flops, "bytes_dev": mem_bytes, "coll_bytes_dev": coll,
    }
    arg = full.get("argument_size_in_bytes", 0)
    tmp = full.get("temp_size_in_bytes", 0)
    out = full.get("output_size_in_bytes", 0)
    rec["mem_args_gb"] = arg / 2**30
    rec["mem_temp_gb"] = tmp / 2**30
    rec["fits_16g"] = (arg + tmp) <= 16 * 2**30
    if flops is None:
        return rec

    t_c = flops / PEAK_FLOPS
    t_m = mem_bytes / HBM_BW
    t_l = coll / LINK_BW
    rec.update(t_compute=t_c, t_memory=t_m, t_collective=t_l)
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    rec["dominant"] = max(terms, key=terms.get)
    rec["bound_s"] = max(terms.values())

    # useful model flops (6ND), per device
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # prefill is fwd-only: model flops 2ND
    else:
        tokens = shape.global_batch  # one token per sequence per step
    n_eff = active_p if cfg.num_experts else total_p
    per_tok = 6 if shape.kind == "train" else 2
    model_flops_dev = per_tok * n_eff * tokens / CHIPS
    rec["model_flops_dev"] = model_flops_dev
    rec["useful_ratio"] = model_flops_dev / flops if flops else 0.0
    rec["roofline_frac"] = (model_flops_dev / PEAK_FLOPS) / rec["bound_s"]
    if shape.kind == "decode":
        # bandwidth-roofline view: irreducible bytes = params + KV read
        kv_bytes = 0.0
        if full.get("pool_pages"):
            K, hd = cfg.num_kv_heads, cfg.head_dim
            attn_layers = sum(1 for i in range(cfg.num_layers)
                              if (cfg.family != "ssm") and
                              (cfg.family != "hybrid" or cfg.is_attn_layer(i)))
            kv_bytes = (full["pool_pages"] * 2048 * K * hd * 2 * 2
                        * attn_layers / CHIPS)
        par_bytes = n_eff * 2 / CHIPS
        rec["min_bytes_dev"] = par_bytes + kv_bytes
        rec["mem_roofline_frac"] = min(
            (par_bytes + kv_bytes) / mem_bytes, 1.0) if mem_bytes else 0.0
    return rec


def assemble(art_dir="artifacts/dryrun", out_csv="artifacts/roofline.csv"):
    from repro.configs import cells
    art = Path(art_dir)
    rows = [assemble_cell(art, a, s) for a, s in cells()]
    cols = ["arch", "shape", "dominant", "t_compute", "t_memory",
            "t_collective", "bound_s", "useful_ratio", "roofline_frac",
            "mem_roofline_frac", "mem_args_gb", "mem_temp_gb", "fits_16g",
            "multi_pod_ok"]
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(
            f"{r.get(c):.6g}" if isinstance(r.get(c), float)
            else str(r.get(c, "")) for c in cols))
    Path(out_csv).parent.mkdir(parents=True, exist_ok=True)
    Path(out_csv).write_text("\n".join(lines))
    return rows


def markdown_table(rows):
    hdr = ("| arch | shape | dominant | compute s | memory s | coll s | "
           "useful | roofline | fits16G | multi-pod |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAILED |||||||||")
            continue
        fmt = lambda x: f"{x:.3e}" if isinstance(x, float) else "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('dominant', '—')} | "
            f"{fmt(r.get('t_compute'))} | {fmt(r.get('t_memory'))} | "
            f"{fmt(r.get('t_collective'))} | "
            f"{r.get('useful_ratio', 0) or 0:.2f} | "
            f"{r.get('roofline_frac', 0) or 0:.3f} | "
            f"{'Y' if r.get('fits_16g') else 'N'} | "
            f"{'Y' if r.get('multi_pod_ok') else 'N'} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = assemble()
    print(markdown_table(rows))
