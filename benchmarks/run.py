"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract) for:
  fig4  — bucket-length distribution (paper Fig. 4)
  fig5  — CPU data-structure probe times, measured (paper Fig. 5)
  fig6  — HashMem modeled speedups vs paper's claims (paper Fig. 6)
  kern  — probe-kernel VMEM footprints + interpret-mode timings (§4.3 analogue)
  roofline — per-cell terms from dry-run artifacts, if present (§Roofline)
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _emit(name, us, derived):
    print(f"{name},{us},{derived}")


def main() -> None:
    from benchmarks import fig4_buckets, fig5_cpu_baselines, fig6_hashmem
    from benchmarks import kernel_bench

    for r in fig4_buckets.run(n_words=30_000):
        _emit(r["name"], "",
              f"cv={r['cv']:.3f};max={r['max_len']};"
              f"under={r['frac_under_half']:.2f};over={r['frac_over_2x']:.2f}")

    measured = fig5_cpu_baselines.run(n=1 << 20)
    for r in measured:
        _emit(r["name"], f"{r['us_per_probe']:.4f}", "measured on container")

    for r in fig6_hashmem.run(measured_cpu=measured):
        derived = ";".join(f"{k}={v}" for k, v in r.items() if k != "name")
        _emit(r["name"], f"{r.get('ns_per_probe', 0) / 1e3:.5f}"
              if "ns_per_probe" in r else "", derived)

    for r in kernel_bench.run():
        _emit(r["name"], f"{r.get('us_per_probe', '')}",
              ";".join(f"{k}={v}" for k, v in r.items()
                       if k not in ("name", "us_per_probe")))

    # roofline from the self-consistent optimized grid (falls back to the
    # default dry-run dir); baseline-vs-opt comparison: benchmarks/perf_compare
    root = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    art = os.path.join(root, "dryrun_opt")
    if not os.path.isdir(art):
        art = os.path.join(root, "dryrun")
    if os.path.isdir(art) and len(os.listdir(art)) > 10:
        from benchmarks import roofline
        rows = roofline.assemble(art_dir=art)
        for r in rows:
            if not r.get("ok") or r.get("flops_dev") is None:
                continue
            _emit(f"roofline_{r['arch']}_{r['shape']}", "",
                  f"dominant={r['dominant']};bound_s={r['bound_s']:.4e};"
                  f"roofline_frac={r.get('roofline_frac', 0):.4f};"
                  f"useful={r.get('useful_ratio', 0):.3f}")


if __name__ == "__main__":
    main()
