"""Serving-engine benchmark: coalesced ticks vs per-request HashMem calls.

Drives the multi-tenant continuous-batching engine (repro.serving) with the
YCSB-style loadgen twice over the SAME request stream:

  * ``coalesced``   — the engine's step-level coalescing: at most one
    vectorized probe/delete/insert call per shard per tick;
  * ``per_request`` — identical schedule, but one HashMem call per op
    (``coalesce=False``), i.e. the synchronous one-op-per-host-call serving
    loop this PR replaces.

The acceptance bar (ISSUE 4): at 64 concurrent requests the coalesced
engine sustains >= 5x the ops/sec of the per-request baseline — batching
turns O(requests) host<->device round trips per tick into O(1).

``--json`` APPENDS this run to ``BENCH_serving.json`` (a ``runs`` list), so
the file keeps a per-PR perf trajectory like BENCH_kernels.json.
"""
from __future__ import annotations

import argparse
import time

from bench_util import append_run

from repro.serving import build_ycsb_engine


def run_mode(*, coalesce, workloads, slots, shards, record_count,
             ops_per_request, requests, seed) -> dict:
    eng, gens = build_ycsb_engine(workloads, slots=slots, shards=shards,
                                  record_count=record_count,
                                  ops_per_request=ops_per_request,
                                  coalesce=coalesce, seed=seed)
    per = requests // len(gens)
    reqs = [r for g in gens for r in g.requests(per)]
    # warmup: an identical engine (same config, slots => same padded batch
    # shapes) compiles every op-kind trace outside the timed window — the
    # module-level jit cache is shared, so the measured run is steady-state
    warm, wgens = build_ycsb_engine(workloads, slots=slots, shards=shards,
                                    record_count=record_count,
                                    ops_per_request=ops_per_request,
                                    coalesce=coalesce, seed=seed + 997)
    warm.submit_all([r for g in wgens for r in g.requests(2 * slots
                                                          // len(wgens))])
    warm.run()

    t0 = time.perf_counter()
    eng.submit_all(reqs)
    snap = eng.run()
    wall = time.perf_counter() - t0
    name = "coalesced" if coalesce else "per_request"
    return {
        "name": f"serving_{''.join(workloads)}_{slots}slots_{name}",
        "mode": name,
        "concurrency": slots,
        "shards": shards,
        "requests": len(reqs),
        "total_ops": snap["total_ops"],
        "ticks": snap["ticks"],
        "wall_seconds": wall,
        "ops_per_sec": snap["total_ops"] / wall if wall > 0 else 0.0,
        "hashmem_calls": dict(eng.batch_calls),
        "calls_per_tick": sum(eng.batch_calls.values()) / max(snap["ticks"], 1),
        "request_latency_ticks_p50": snap["request_latency_ticks"]["p50"],
        "request_latency_ticks_p99": snap["request_latency_ticks"]["p99"],
        "request_latency_ms_p50": snap["request_latency_ms"]["p50"],
        "request_latency_ms_p99": snap["request_latency_ms"]["p99"],
        "occupancy_mean": snap["occupancy"]["mean"],
        "probe_hit_rate": snap["probe_hit_rate"],
        "grow_events": eng.grow_events,
        "compact_events": eng.compact_events,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="append this run to BENCH_serving.json")
    ap.add_argument("--out", default=None,
                    help="JSON output path (implies --json)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--slots", type=int, default=64,
                    help="concurrent request slots (acceptance bar: 64)")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--record-count", type=int, default=2048)
    ap.add_argument("--ops-per-request", type=int, default=4)
    ap.add_argument("--workloads", default="A,B,E")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (make ci)")
    args = ap.parse_args()
    if args.out is not None:
        args.json = True
    args.out = args.out or "BENCH_serving.json"
    if args.smoke:
        args.requests, args.slots, args.record_count = 16, 8, 256

    wls = [w.strip().upper() for w in args.workloads.split(",") if w.strip()]
    kw = dict(workloads=wls, slots=args.slots, shards=args.shards,
              record_count=args.record_count,
              ops_per_request=args.ops_per_request, requests=args.requests,
              seed=args.seed)
    co = run_mode(coalesce=True, **kw)
    pr = run_mode(coalesce=False, **kw)
    speedup = co["ops_per_sec"] / pr["ops_per_sec"] if pr["ops_per_sec"] \
        else float("inf")
    rows = [co, pr,
            {"name": f"serving_speedup_{args.slots}slots",
             "coalesced_ops_per_sec": co["ops_per_sec"],
             "per_request_ops_per_sec": pr["ops_per_sec"],
             "speedup": speedup,
             "meets_5x_bar": speedup >= 5.0}]
    for r in rows:
        print(r)
    if args.json:
        n = append_run(args.out, {
            "bench": "serving",
            "concurrency": args.slots,
            "requests": args.requests,
            "workloads": wls,
            "speedup_coalesced_vs_per_request": speedup,
            "rows": rows,
        })
        print(f"appended run #{n} -> {args.out}")


if __name__ == "__main__":
    main()
