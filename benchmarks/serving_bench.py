"""Serving-engine benchmark: coalesced ticks vs per-request HashMem calls,
plus multi-tick op pipelining and (optionally) mesh-backed shards.

Drives the multi-tenant continuous-batching engine (repro.serving) with the
YCSB-style loadgen over the SAME request stream in several modes:

  * ``coalesced``   — the engine's step-level coalescing: at most one
    vectorized probe/delete/insert call per shard per tick;
  * ``per_request`` — identical schedule, but one HashMem call per op
    (``coalesce=False``), i.e. the synchronous one-op-per-host-call serving
    loop PR 3 replaced;
  * ``pipelined``   — coalesced + pipeline_depth=2 (tick N+1's phases
    issued while tick N's results are in flight; write-claim fence);
  * ``--mesh-shards N`` adds mesh-backed rows (one rlu shard_map call per
    phase per tick) — needs N jax devices, e.g.
    XLA_FLAGS=--xla_force_host_platform_device_count=N.

The PR-3 acceptance bar: at 64 concurrent requests the coalesced engine
sustains >= 5x the ops/sec of the per-request baseline.

``--json`` APPENDS this run to ``BENCH_serving.json`` (a ``runs`` list), so
the file keeps a per-PR perf trajectory like BENCH_kernels.json
(tools/bench_check.py guards it against regressions).
"""
from __future__ import annotations

import argparse
import time

from bench_util import append_run

from repro.serving import build_ycsb_engine


def run_mode(*, coalesce, workloads, slots, shards, record_count,
             ops_per_request, requests, seed, pipeline=1, mesh=None,
             tag="") -> dict:
    kw = dict(slots=slots, shards=shards, record_count=record_count,
              ops_per_request=ops_per_request, coalesce=coalesce,
              pipeline_depth=pipeline, mesh=mesh)
    eng, gens = build_ycsb_engine(workloads, seed=seed, **kw)
    per = requests // len(gens)
    reqs = [r for g in gens for r in g.requests(per)]
    # warmup: an identical engine (same config, slots => same padded batch
    # shapes) compiles every op-kind trace outside the timed window — the
    # module-level jit cache is shared, so the measured run is steady-state
    warm, wgens = build_ycsb_engine(workloads, seed=seed + 997, **kw)
    warm.submit_all([r for g in wgens for r in g.requests(2 * slots
                                                          // len(wgens))])
    warm.run()

    t0 = time.perf_counter()
    eng.submit_all(reqs)
    snap = eng.run()
    wall = time.perf_counter() - t0
    name = tag or ("coalesced" if coalesce else "per_request")
    return {
        "name": f"serving_{''.join(workloads)}_{slots}slots_{name}",
        "mode": name,
        "pipeline_depth": pipeline,
        "mesh_shards": eng.num_shards if mesh is not None else 0,
        "stall_events": eng.stall_events,
        "concurrency": slots,
        "shards": shards,
        "requests": len(reqs),
        "total_ops": snap["total_ops"],
        "ticks": snap["ticks"],
        "wall_seconds": wall,
        "ops_per_sec": snap["total_ops"] / wall if wall > 0 else 0.0,
        "hashmem_calls": dict(eng.batch_calls),
        "calls_per_tick": sum(eng.batch_calls.values()) / max(snap["ticks"], 1),
        "request_latency_ticks_p50": snap["request_latency_ticks"]["p50"],
        "request_latency_ticks_p99": snap["request_latency_ticks"]["p99"],
        "request_latency_ms_p50": snap["request_latency_ms"]["p50"],
        "request_latency_ms_p99": snap["request_latency_ms"]["p99"],
        "occupancy_mean": snap["occupancy"]["mean"],
        "probe_hit_rate": snap["probe_hit_rate"],
        "grow_events": eng.grow_events,
        "compact_events": eng.compact_events,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="append this run to BENCH_serving.json")
    ap.add_argument("--out", default=None,
                    help="JSON output path (implies --json)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--slots", type=int, default=64,
                    help="concurrent request slots (acceptance bar: 64)")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--record-count", type=int, default=2048)
    ap.add_argument("--ops-per-request", type=int, default=4)
    ap.add_argument("--workloads", default="A,B,E")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="also bench mesh-backed shards (needs that many "
                         "jax devices; see module docstring)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (make ci)")
    args = ap.parse_args()
    if args.out is not None:
        args.json = True
    args.out = args.out or "BENCH_serving.json"
    if args.smoke:
        args.requests, args.slots, args.record_count = 16, 8, 256

    wls = [w.strip().upper() for w in args.workloads.split(",") if w.strip()]
    kw = dict(workloads=wls, slots=args.slots, shards=args.shards,
              record_count=args.record_count,
              ops_per_request=args.ops_per_request, requests=args.requests,
              seed=args.seed)
    co = run_mode(coalesce=True, **kw)
    pr = run_mode(coalesce=False, **kw)
    pi = run_mode(coalesce=True, pipeline=2, tag="pipelined", **kw)
    rows = [co, pr, pi]
    if args.mesh_shards:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.mesh_shards)
        rows.append(run_mode(coalesce=True, mesh=mesh, tag="mesh", **kw))
        rows.append(run_mode(coalesce=True, mesh=mesh, pipeline=2,
                             tag="mesh_pipelined", **kw))
    speedup = co["ops_per_sec"] / pr["ops_per_sec"] if pr["ops_per_sec"] \
        else float("inf")
    rows.append({"name": f"serving_speedup_{args.slots}slots",
                 "coalesced_ops_per_sec": co["ops_per_sec"],
                 "per_request_ops_per_sec": pr["ops_per_sec"],
                 "pipelined_ops_per_sec": pi["ops_per_sec"],
                 "speedup": speedup,
                 "pipelined_vs_coalesced":
                     pi["ops_per_sec"] / co["ops_per_sec"]
                     if co["ops_per_sec"] else float("inf"),
                 "meets_5x_bar": speedup >= 5.0})
    for r in rows:
        print(r)
    if args.json:
        n = append_run(args.out, {
            "bench": "serving",
            "concurrency": args.slots,
            "requests": args.requests,
            "workloads": wls,
            "speedup_coalesced_vs_per_request": speedup,
            "rows": rows,
        })
        print(f"appended run #{n} -> {args.out}")


if __name__ == "__main__":
    main()
