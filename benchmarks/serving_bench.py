"""Serving-engine benchmark: coalesced ticks vs per-request HashMem calls,
plus multi-tick op pipelining and (optionally) mesh-backed shards.

Drives the multi-tenant continuous-batching engine (repro.serving) with the
YCSB-style loadgen over the SAME request stream in several modes:

  * ``coalesced``   — the engine's step-level coalescing: at most one
    vectorized probe/delete/insert call per shard per tick;
  * ``per_request`` — identical schedule, but one HashMem call per op
    (``coalesce=False``), i.e. the synchronous one-op-per-host-call serving
    loop PR 3 replaced;
  * ``pipelined``   — coalesced + pipeline_depth=2 (tick N+1's phases
    issued while tick N's results are in flight; write-claim fence);
  * ``--mesh-shards N`` adds mesh-backed rows — ``mesh`` /
    ``mesh_pipelined`` run the three-call per-phase path
    (``fused_tick=False``, one shard_map per phase per tick, the pre-fused
    baseline) and ``mesh_fused`` / ``mesh_fused_pipelined`` run the fused
    whole-tick megakernel (ONE shard_map for probe+delete+insert, the
    engine default) with two-pass skew-aware routing; fused rows carry
    ``route_cap_*`` telemetry showing the routed ICI capacity tracking the
    measured key skew instead of the Q_local worst case.  When the process
    has fewer than N jax devices, the mesh rows run in a CHILD process
    with --xla_force_host_platform_device_count=N — forcing host devices
    in THIS process would split the CPU for the host-shard rows too and
    poison their trajectory against single-device prior runs.

The PR-3 acceptance bar: at 64 concurrent requests the coalesced engine
sustains >= 5x the ops/sec of the per-request baseline.  The ISSUE-6
launch-count bar: fused mesh rows show calls_per_tick 1 vs 3.

``--json`` APPENDS this run to ``BENCH_serving.json`` (a ``runs`` list), so
the file keeps a per-PR perf trajectory like BENCH_kernels.json
(tools/bench_check.py guards it against regressions).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from bench_util import append_run

from repro.serving import build_ycsb_engine


def _ratio(num: float, den: float) -> float:
    """num/den with a finite 0.0 fallback — ``float("inf")`` here used to
    reach json.dumps, which emits ``Infinity`` (not valid JSON) and
    corrupts the BENCH trajectory file."""
    return num / den if den > 0 else 0.0


def run_mode(*, coalesce, workloads, slots, shards, record_count,
             ops_per_request, requests, seed, pipeline=1, mesh=None,
             fused=None, tag="", repeats=3, trace=None) -> dict:
    kw = dict(slots=slots, shards=shards, record_count=record_count,
              ops_per_request=ops_per_request, coalesce=coalesce,
              pipeline_depth=pipeline, mesh=mesh, fused_tick=fused,
              trace=trace)
    # warmup: an identical engine REPLAYS the same request stream, so every
    # trace the timed runs will see — op-kind combos, pipeline stall/drain
    # shapes, and (fused mesh rows) the exact routed-capacity tuples baked
    # in by two-pass routing — is compiled outside the timed window; the
    # module-level jit cache is shared, so the measured runs are
    # steady-state.  (A shorter different-seed warmup leaves pipelined rows
    # paying first-compile inside the wall clock.)
    warm, wgens = build_ycsb_engine(workloads, seed=seed, **kw)
    per = requests // len(wgens)
    warm.submit_all([r for g in wgens for r in g.requests(per)])
    warm.run()

    # time the serving drain loop only, best of ``repeats`` fresh engines
    # over the identical stream (the min-of-N discipline kernel_bench uses):
    # a drain is a dozen ticks / tens of ms, so a single GC pause or
    # scheduler hiccup mid-run swings a one-shot reading 2-3x.  The eng.run()
    # call on the already-drained winner just takes the forced end-of-run
    # telemetry sample (chain depth / rows activated) + snapshot, OUTSIDE
    # the timed window.
    wall, eng, reqs = float("inf"), None, None
    for _ in range(max(repeats, 1)):
        e, gens = build_ycsb_engine(workloads, seed=seed, **kw)
        rq = [r for g in gens for r in g.requests(per)]
        t0 = time.perf_counter()
        e.submit_all(rq)
        while not e.pool.idle() and e.ticks < 100_000:
            e.tick()
        e.flush()
        w = time.perf_counter() - t0
        if w < wall:
            wall, eng, reqs = w, e, rq
    snap = eng.run()
    name = tag or ("coalesced" if coalesce else "per_request")
    # two-pass routing telemetry (fused mesh rows): how far the measured
    # per-(src,dst) capacity sits below the Q_local worst-case padding
    route = {}
    if eng.route_cap_log:
        caps = [c for rec in eng.route_cap_log for c in rec["cap"]]
        qls = [q for rec in eng.route_cap_log for q in rec["q_local"]]
        route = {
            "route_cap_mean": sum(caps) / len(caps),
            "route_cap_max": max(caps),
            "route_cap_q_local_max": max(qls),
            "route_cap_fill": _ratio(sum(caps), sum(qls)),
        }
    return {
        "name": f"serving_{''.join(workloads)}_{slots}slots_{name}",
        "mode": name,
        "pipeline_depth": pipeline,
        "mesh_shards": eng.num_shards if mesh is not None else 0,
        "stall_events": eng.stall_events,
        "concurrency": slots,
        "shards": shards,
        "requests": len(reqs),
        "total_ops": snap["total_ops"],
        "ticks": snap["ticks"],
        "wall_seconds": wall,
        "ops_per_sec": snap["total_ops"] / wall if wall > 0 else 0.0,
        "hashmem_calls": dict(eng.batch_calls),
        "calls_per_tick": sum(eng.batch_calls.values()) / max(snap["ticks"], 1),
        "request_latency_ticks_p50": snap["request_latency_ticks"]["p50"],
        "request_latency_ticks_p99": snap["request_latency_ticks"]["p99"],
        "request_latency_ms_p50": snap["request_latency_ms"]["p50"],
        "request_latency_ms_p99": snap["request_latency_ms"]["p99"],
        "occupancy_mean": snap["occupancy"]["mean"],
        "probe_hit_rate": snap["probe_hit_rate"],
        "grow_events": eng.grow_events,
        "compact_events": eng.compact_events,
        "chain_depth_p50": snap["chain_depth"]["p50"],
        "chain_depth_p99": snap["chain_depth"]["p99"],
        "rows_activated_p50": snap["rows_activated"]["p50"],
        "rows_activated_p99": snap["rows_activated"]["p99"],
        **route,
    }


def trace_overhead_row(*, workloads, slots, shards, record_count,
                       ops_per_request, requests, seed, repeats=5) -> dict:
    """Traced vs untraced wall time over the IDENTICAL coalesced stream.
    The two sides are A/B INTERLEAVED (untraced, traced, untraced, ...)
    and each takes its min-of-N: a serving drain is tens of ms, so
    measuring the traced side after the untraced side finishes would fold
    allocator/jit-cache/scheduler drift into the ratio and report it as
    tracer cost.  ``trace_overhead`` is the resulting wall ratio
    (lower-better, 1.0 = free), gated <=1.10x by tools/bench_check.py
    ABS_BARS."""
    kw = dict(slots=slots, shards=shards, record_count=record_count,
              ops_per_request=ops_per_request, coalesce=True)
    walls = {False: float("inf"), True: float("inf")}
    total_ops = 0
    for rep in range(-1, max(repeats, 1)):      # rep -1 warms both paths
        for traced in (False, True):
            eng, gens = build_ycsb_engine(workloads, seed=seed,
                                          trace=traced, **kw)
            per = requests // len(gens)
            rq = [r for g in gens for r in g.requests(per)]
            t0 = time.perf_counter()
            eng.submit_all(rq)
            while not eng.pool.idle() and eng.ticks < 100_000:
                eng.tick()
            eng.flush()
            wall = time.perf_counter() - t0
            if rep >= 0 and wall < walls[traced]:
                walls[traced] = wall
            total_ops = eng.metrics.total_ops
    overhead = _ratio(walls[True], walls[False])
    return {"name": f"serving_trace_{slots}slots",
            "untraced_ops_per_sec": _ratio(total_ops, walls[False]),
            "traced_ops_per_sec": _ratio(total_ops, walls[True]),
            "trace_overhead": overhead,
            "meets_trace_bar": overhead <= 1.10}


def growth_row(*, seed=7, repeats=3, slots=16) -> dict:
    """p99 under growth: the IDENTICAL zipfian insert-heavy stream through
    two engines differing ONLY in ``cfg.resize``.  The stream inserts ~500
    hot-skewed keys into an 8-bucket table with a 2-page chain bound, so
    the table must resize many times mid-serving:

      * ``rebuild``     — every repair is a stop-the-world ``grow()``
        rehash of the whole (thousands-of-pages) arena: the requests in
        flight during that tick absorb the rebuild wall time;
      * ``extendible``  — the hot GROUP splits alone (and the directory
        doubles by pointer copy, >= 4 doublings on this stream), so no
        request ever waits on a full rehash.

    The A/B is interleaved (same min-of-N discipline as trace_overhead_row)
    and the acceptance gate is ``p99_growth_ratio`` = extendible p99 ms /
    rebuild p99 ms, hard-bounded < 1.0 by tools/bench_check.py ABS_BARS —
    the raw per-mode ``*request_latency*`` fields are wall-clock noise and
    stay unguarded (SKIP).  Request latency in TICKS is schedule-determined
    and must be identical between the modes (reported as a sanity pair).
    """
    import dataclasses

    import numpy as np

    from repro.configs.base import HashMemConfig
    from repro.serving import Request, ServingEngine

    def streams():
        # mirrors tests/model.make_insert_heavy_schedule (tests/ is not on
        # the bench path): insert-dominated, zipf-skewed key choice so the
        # chain overflow concentrates on hot buckets
        rng = np.random.default_rng(seed)
        keyspace = 4096
        w = 1.0 / np.arange(1, keyspace + 1, dtype=np.float64) ** 0.6
        w /= w.sum()
        probs = [0.8, 0.08, 0.08, 0.04]             # insert/update/read/del
        reqs = []
        for _ in range(128):
            ops = []
            for _ in range(5):
                k = int(rng.choice(keyspace, p=w))
                v = int(rng.integers(1, 2 ** 20))
                kind = ["insert", "update", "read", "delete"][
                    int(rng.choice(4, p=probs))]
                ops.append({"insert": ("insert", k, v),
                            "update": ("update", k, v),
                            "read": ("read", k),
                            "delete": ("delete", k)}[kind])
            reqs.append(ops)
        return reqs

    # one small hot table, arena sized with split-leak slack (a split
    # abandons its old overflow pages until compact/grow reclaims them)
    base = HashMemConfig(num_buckets=8, slots_per_page=4,
                         overflow_pages=2040, max_chain=2, backend="ref",
                         auto_grow=True, max_load_factor=1.0)
    best = {m: None for m in ("rebuild", "extendible")}
    for rep in range(-1, max(repeats, 1)):          # rep -1 warms both
        for mode in ("rebuild", "extendible"):
            cfg = dataclasses.replace(base, resize=mode)
            eng = ServingEngine(cfg, max_slots=slots)
            eng.submit_all([Request(ops=ops) for ops in streams()])
            while not eng.pool.idle() and eng.ticks < 100_000:
                eng.tick()
            eng.flush()
            snap = eng.run()
            if rep < 0:
                continue
            p99 = snap["request_latency_ms"]["p99"]
            if best[mode] is None or p99 < best[mode]["p99_ms"]:
                best[mode] = {
                    "p99_ms": p99,
                    "p50_ms": snap["request_latency_ms"]["p50"],
                    "p99_ticks": snap["request_latency_ticks"]["p99"],
                    "grow_events": eng.grow_events,
                    "splits": eng.split_events,
                    "doublings": eng.directory_doublings,
                }
    reb, ext = best["rebuild"], best["extendible"]
    # the stream must actually force growth in BOTH modes, >= 4 directory
    # doublings extendible-side (the ISSUE acceptance shape) and zero
    # stop-the-world rebuilds on the extendible engine
    assert reb["grow_events"] >= 1, reb
    assert ext["doublings"] >= 4 and ext["splits"] >= 4, ext
    assert ext["grow_events"] == 0, ext
    return {
        "name": f"serving_p99_under_growth_{slots}slots",
        "rebuild_grow_events": reb["grow_events"],
        "extendible_splits": ext["splits"],
        "extendible_doublings": ext["doublings"],
        "rebuild_request_latency_ms_p50": reb["p50_ms"],
        "rebuild_request_latency_ms_p99": reb["p99_ms"],
        "extendible_request_latency_ms_p50": ext["p50_ms"],
        "extendible_request_latency_ms_p99": ext["p99_ms"],
        "rebuild_request_latency_ticks_p99": reb["p99_ticks"],
        "extendible_request_latency_ticks_p99": ext["p99_ticks"],
        "p99_growth_ratio": _ratio(ext["p99_ms"], reb["p99_ms"]),
    }


def _mesh_rows(num_shards: int, slots: int, kw: dict) -> list:
    """mesh/mesh_pipelined (per-phase baseline) + mesh_fused rows, plus the
    fused-vs-unfused comparison row.  Needs ``num_shards`` jax devices."""
    from repro.launch.mesh import make_serving_mesh
    mesh = make_serving_mesh(num_shards)
    # per-phase baseline (fused=False: 3 shard_map launches per tick)
    mu = run_mode(coalesce=True, mesh=mesh, fused=False, tag="mesh", **kw)
    mp = run_mode(coalesce=True, mesh=mesh, fused=False, pipeline=2,
                  tag="mesh_pipelined", **kw)
    # fused whole-tick megakernel (engine default: ONE launch per tick)
    mf = run_mode(coalesce=True, mesh=mesh, tag="mesh_fused", **kw)
    mfp = run_mode(coalesce=True, mesh=mesh, pipeline=2,
                   tag="mesh_fused_pipelined", **kw)
    cmp_row = {"name": f"serving_fused_tick_{slots}slots",
               "launches_per_tick_unfused": mu["calls_per_tick"],
               "launches_per_tick_fused": mf["calls_per_tick"],
               "fused_vs_unfused_throughput_ratio":
                   _ratio(mf["ops_per_sec"], mu["ops_per_sec"]),
               "route_cap_fill": mf.get("route_cap_fill", 1.0)}
    return [mu, mp, mf, mfp, cmp_row]


def _mesh_block(args, kw: dict) -> list:
    """Run the mesh rows inline when this process already has enough jax
    devices; otherwise re-exec this script in a CHILD process with
    --xla_force_host_platform_device_count (forcing host devices in the
    parent would split the CPU under the host-shard rows too, poisoning
    their trajectory against single-device prior runs)."""
    import jax
    if jax.device_count() >= args.mesh_shards:
        return _mesh_rows(args.mesh_shards, args.slots, kw)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count="
                        f"{args.mesh_shards}").strip()
    cmd = [sys.executable, os.path.abspath(__file__), "--mesh-rows-json",
           "--mesh-shards", str(args.mesh_shards),
           "--requests", str(args.requests), "--slots", str(args.slots),
           "--shards", str(args.shards),
           "--record-count", str(args.record_count),
           "--ops-per-request", str(args.ops_per_request),
           "--workloads", args.workloads, "--seed", str(args.seed)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"mesh-row child failed:\n{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="append this run to BENCH_serving.json")
    ap.add_argument("--out", default=None,
                    help="JSON output path (implies --json)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--slots", type=int, default=64,
                    help="concurrent request slots (acceptance bar: 64)")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--record-count", type=int, default=2048)
    ap.add_argument("--ops-per-request", type=int, default=4)
    ap.add_argument("--workloads", default="A,B,E")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="also bench mesh-backed shards (needs that many "
                         "jax devices; see module docstring)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (make ci)")
    ap.add_argument("--growth", action="store_true",
                    help="force the p99-under-growth A/B row (always on "
                         "for non-smoke runs)")
    ap.add_argument("--mesh-rows-json", action="store_true",
                    help=argparse.SUPPRESS)  # child mode: emit mesh rows
    args = ap.parse_args()
    if args.out is not None:
        args.json = True
    args.out = args.out or "BENCH_serving.json"
    if args.smoke:
        args.requests, args.slots, args.record_count = 16, 8, 256

    wls = [w.strip().upper() for w in args.workloads.split(",") if w.strip()]
    kw = dict(workloads=wls, slots=args.slots, shards=args.shards,
              record_count=args.record_count,
              ops_per_request=args.ops_per_request, requests=args.requests,
              seed=args.seed)
    if args.mesh_rows_json:
        print(json.dumps(_mesh_rows(args.mesh_shards, args.slots, kw)))
        return
    co = run_mode(coalesce=True, **kw)
    pr = run_mode(coalesce=False, **kw)
    pi = run_mode(coalesce=True, pipeline=2, tag="pipelined", **kw)
    # trace_overhead: the SAME coalesced stream with span recording on —
    # the observability layer's cost as a measured ratio, gated <=1.10x by
    # tools/bench_check.py (ABS_BARS), never assumed
    trace_row = trace_overhead_row(**kw)
    rows = [co, pr, pi]
    if args.growth or not args.smoke:
        # latency-bounded growth acceptance: extendible p99 strictly below
        # rebuild p99 on a >=4-doubling insert storm (bench_check ABS bar)
        rows.append(growth_row(seed=args.seed + 7))
    if args.mesh_shards:
        rows += _mesh_block(args, kw)
    speedup = _ratio(co["ops_per_sec"], pr["ops_per_sec"])
    rows.append({"name": f"serving_speedup_{args.slots}slots",
                 "coalesced_ops_per_sec": co["ops_per_sec"],
                 "per_request_ops_per_sec": pr["ops_per_sec"],
                 "pipelined_ops_per_sec": pi["ops_per_sec"],
                 "speedup": speedup,
                 "pipelined_vs_coalesced":
                     _ratio(pi["ops_per_sec"], co["ops_per_sec"]),
                 "meets_5x_bar": speedup >= 5.0})
    rows.append(trace_row)
    for r in rows:
        print(r)
    if args.json:
        n = append_run(args.out, {
            "bench": "serving",
            "concurrency": args.slots,
            "requests": args.requests,
            "workloads": wls,
            "speedup_coalesced_vs_per_request": speedup,
            "rows": rows,
        })
        print(f"appended run #{n} -> {args.out}")


if __name__ == "__main__":
    main()
