"""Analytic DDR4 timing model for HashMem probes.

The paper itself models HashMem from DRAM timing data of prior work
(§4.1: "we analyzed the timing data gathered from prior works [1,6,7,14]")
— it was never fabricated.  This module reproduces that methodology with
explicit, auditable assumptions:

  per-probe subarray latency
    area-opt    : tRCD + ceil(occupied_slots) * tCCD_S + t_latch
                  (element-serial walk at column-access cadence)
    perf-opt    : tRCD + n_cam_ticks * t_tick   (whole row CAM compare,
                  "single or small number of clock ticks", paper §2.2)
    bit-serial  : tRCD + key_bits * t_tick      (one bit-plane per step)

  end-to-end throughput for a probe stream
    parallel service rate : n_subarrays / t_probe   (RLU spreads probes)
    channel rate          : channel_BW / bus_bytes_per_probe
                            (cmd+key down, padded cache line back, §2.5)
    probes/s = min(parallel, channel)

  CPU reference (paper's Xeon-class DRAM-bound probe)
    t_cpu = accesses_per_probe * t_rand_access
    where t_rand_access ≈ tRCD + tCAS + burst + queueing.

All constants from the DDR4_8Gb_3200 column of the JEDEC/DRAMsim3 tables
(configs/hashmem_paper.DDR4_TIMING).
"""
from __future__ import annotations


from repro.configs.hashmem_paper import DDR4_TIMING as T

N_BANKS = 8
N_SUBARRAYS_PER_BANK = 128
T_LATCH_NS = 5.0
T_TICK_NS = 2.0          # CAM / bit-plane tick (500 MHz PIM clock)
CAM_TICKS = 2
BUS_BYTES_PER_PROBE = 8 + 64   # key+cmd down, padded cache line back
RAND_ACCESS_QUEUE_NS = 55.0    # measured-average DRAM random access ~100ns
T_FAW_NS = 21.25               # four-activation window (DDR4-3200)
# Shared per-probe overhead (MC command + translation + result delivery to
# LLC).  The paper's own area:perf speedup ratio (49.1/17.1 = 2.87x) together
# with our subarray latencies implies ~470 ns of variant-independent overhead
# in their (unpublished) model; we adopt that as the calibrated default and
# expose it as a parameter.  See EXPERIMENTS.md §Paper-validation.
T_OVERHEAD_NS = 470.0


def probe_latency_ns(variant: str, occupied_slots: float, key_bits: int = 32,
                     chain_pages: float = 1.0) -> float:
    """Latency of one bucket traversal at the subarray (chain_pages rows)."""
    act = T["tRCD"]
    if variant == "area":
        per_row = act + occupied_slots * T["tCCD_S"] + T_LATCH_NS
    elif variant == "perf":
        per_row = act + CAM_TICKS * T_TICK_NS + T_LATCH_NS
    elif variant == "bitserial":
        per_row = act + key_bits * T_TICK_NS + T_LATCH_NS
    else:
        raise ValueError(variant)
    return per_row * chain_pages + T["tRP"]


def hashmem_latency_ns(variant: str, occupied_slots: float,
                       key_bits: int = 32, chain_pages: float = 1.0,
                       overhead_ns: float = T_OVERHEAD_NS) -> float:
    """End-to-end per-probe latency, probes served serially (the paper's
    evaluation regime: per-probe speedup vs a serial CPU loop)."""
    return overhead_ns + probe_latency_ns(variant, occupied_slots, key_bits,
                                          chain_pages)


def hashmem_throughput(variant: str, occupied_slots: float,
                       key_bits: int = 32, chain_pages: float = 1.0,
                       channels: int = 1) -> dict:
    """Overlapped-probe throughput (beyond-paper analysis): the RLU keeps
    many probes in flight; binding constraints are (a) PE occupancy across
    subarrays, (b) the DDR4 activation-rate window tFAW, (c) channel BW for
    command/result transfer (the paper's §6 channel-parallelism lever)."""
    t_probe = probe_latency_ns(variant, occupied_slots, key_bits, chain_pages)
    n_sub = N_BANKS * N_SUBARRAYS_PER_BANK * channels
    pe_rate = n_sub / (t_probe * 1e-9)
    act_rate = channels * 4 / (T_FAW_NS * 1e-9) / chain_pages
    channel_rate = channels * T["channel_gbps"] * 1e9 / BUS_BYTES_PER_PROBE
    rate = min(pe_rate, act_rate, channel_rate)
    bound = {pe_rate: "subarray", act_rate: "tFAW", channel_rate: "channel"}
    return {
        "variant": variant,
        "t_probe_ns": t_probe,
        "pe_rate_mps": pe_rate / 1e6,
        "act_rate_mps": act_rate / 1e6,
        "channel_rate_mps": channel_rate / 1e6,
        "rate_mps": rate / 1e6,
        "ns_per_probe": 1e9 / rate,
        "bound": bound[rate],
    }


def cpu_probe_ns(accesses_per_probe: float) -> float:
    """DRAM-bound CPU probe model (cache-resident probability ~0 per §4.1.1)."""
    t_access = T["tRCD"] + T["tCAS"] + T["burst_ns"] + RAND_ACCESS_QUEUE_NS
    return accesses_per_probe * t_access


# paper's software baselines, expressed as expected DRAM accesses per probe
CPU_ACCESS_MODEL = {
    "std_map": 26.6,        # red-black tree: log2(1e8) depth, all off-cache
    "unordered_map": 3.0,   # bucket head + node + value indirection
    "hopscotch_map": 1.6,   # open addressing, neighborhood usually 1 line
}
