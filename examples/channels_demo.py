"""Channel-level parallelism demo (paper §6 future work, implemented):
shards a HashMem across 8 virtual devices on the mesh 'model' axis and
routes probes with all_to_all — the RLU fan-out across memory channels.

NOTE: sets XLA_FLAGS before importing jax (standalone script only).

    PYTHONPATH=src python examples/channels_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import HashMemConfig
from repro.core import hashmap, rlu


def main():
    mesh = jax.make_mesh((1, 8), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = HashMemConfig(num_buckets=256, slots_per_page=256,
                        overflow_pages=256, max_chain=4, backend="perf")
    rng = np.random.default_rng(0)
    n = 60_000
    keys = rng.choice(2**31, size=n, replace=False).astype(np.uint32)
    vals = rng.integers(0, 2**31, size=n).astype(np.uint32)

    print("building 8 channel shards (bucket ownership = h mod 8)...")
    hm8 = rlu.build_sharded(cfg, jnp.asarray(keys), jnp.asarray(vals),
                            num_shards=8)

    q = np.concatenate([keys[:4096],
                        (keys[:1024].astype(np.uint64) + 2**31)
                        .astype(np.uint32)])
    with mesh:
        t0 = time.perf_counter()
        v, f = rlu.probe_sharded(mesh, hm8, jnp.asarray(q), cfg)
        v.block_until_ready()
        dt = time.perf_counter() - t0
    v, f = np.asarray(v), np.asarray(f)
    assert f[:4096].all() and (v[:4096] == vals[:4096]).all()
    assert not f[4096:].any()
    print(f"channel-parallel probe of {len(q)} keys across 8 channels: "
          f"hits+misses correct ({dt*1e3:.1f} ms incl. compile)")

    # throughput mode: replicated table, probes sharded over 'data'
    mesh2 = jax.make_mesh((8, 1), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    hm = hashmap.build(cfg, jnp.asarray(keys), jnp.asarray(vals))
    with mesh2:
        v2, f2 = rlu.probe_replicated(mesh2, hm, jnp.asarray(q), cfg,
                                      axis="data")
    assert np.asarray(f2)[:4096].all()
    print("replicated throughput mode: correct on 8-way data sharding")


if __name__ == "__main__":
    main()
