"""Quickstart: build a HashMem, probe it through every backend, mutate it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs.base import HashMemConfig
from repro.core import hashmap


def main():
    # --- the paper's workload, scaled: unique uint32 key/value pairs -----
    rng = np.random.default_rng(0)
    n = 100_000
    keys = rng.choice(2**31, size=n, replace=False).astype(np.uint32)
    vals = rng.integers(0, 2**31, size=n).astype(np.uint32)

    cfg = HashMemConfig(num_buckets=1 << 10, slots_per_page=512,
                        overflow_pages=1 << 8, max_chain=4, backend="perf")
    chk = hashmap.build_check(cfg, keys)
    print(f"build check: max chain {chk['max_chain_needed']}, "
          f"overflow pages {chk['overflow_pages_needed']}, "
          f"load {chk['load_factor']:.2f}")

    # --- bulk build (bucket-per-page layout, overflow chaining) ----------
    hm = hashmap.build(cfg, jnp.asarray(keys), jnp.asarray(vals))

    # --- probe 10% random keys through each compare backend --------------
    q = keys[rng.choice(n, size=n // 10, replace=False)]
    for backend in ("ref", "perf", "area"):
        v, f = hashmap.probe(hm, jnp.asarray(q), backend=backend)
        assert bool(jnp.all(f)), backend
        print(f"probe[{backend:9s}]: {len(q)} keys, all found")

    # --- bit-serial backend needs the column-oriented bit-plane layout ---
    cfg_bs = cfg.__class__(**{**cfg.__dict__, "backend": "bitserial"})
    hm_bs = hashmap.build(cfg_bs, jnp.asarray(keys), jnp.asarray(vals))
    v, f = hashmap.probe(hm_bs, jnp.asarray(q))
    assert bool(jnp.all(f))
    print("probe[bitserial]: all found (b bit-plane steps per probe)")

    # --- delete (tombstones) + insert (pim_malloc overflow) --------------
    hm, found = hashmap.delete(hm, jnp.asarray(keys[:1000]))
    v, f = hashmap.probe(hm, jnp.asarray(keys[:1000]))
    assert not bool(jnp.any(f))
    newk = (keys[:500].astype(np.uint64) + 2**31).astype(np.uint32)
    hm, ok = hashmap.insert(hm, jnp.asarray(newk), jnp.asarray(newk))
    assert bool(jnp.all(ok))
    st = hashmap.stats(hm)
    print(f"after delete+insert: live={st['live_entries']} "
          f"tombstones={st['tombstones']} (not reused, paper §2.5)")


if __name__ == "__main__":
    main()
