"""Three tenants, three YCSB workloads, one HashMem: the multi-tenant
continuous-batching serving engine end to end.

  * "webapp"    — workload A (update-heavy, zipfian) with a tight slot
                  quota, so the engine throttles it instead of letting it
                  starve the others;
  * "analytics" — workload E (short scans, zipfian);
  * "feed"      — workload D (read-latest: reads skew to fresh inserts).

All three share ONE table through tenant-folded keys; every tick coalesces
the whole batch into at most one probe/delete/insert call, and the JSON
telemetry at the end shows per-tenant attribution plus engine-wide
p50/p99 latency, throughput, occupancy, and chain depth.

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import json

from repro.configs.base import HashMemConfig
from repro.serving import (LoadGen, ServingEngine, TenantRegistry,
                           WorkloadSpec, preload_engine)


def main():
    reg = TenantRegistry()
    tenants = [
        (reg.register("webapp", max_slots=6),
         WorkloadSpec("A", record_count=2048, ops_per_request=6)),
        (reg.register("analytics"),
         WorkloadSpec("E", record_count=1024, ops_per_request=4,
                      scan_len=12)),
        (reg.register("feed"),
         WorkloadSpec("D", record_count=1024, ops_per_request=5)),
    ]
    gens = [LoadGen(spec, t, seed=10 + t.tid) for t, spec in tenants]

    eng = ServingEngine(
        HashMemConfig(num_buckets=512, slots_per_page=64,
                      overflow_pages=512, max_chain=8, backend="perf"),
        max_slots=16, max_pending=64, tenants=reg)
    preload_engine(eng, gens)

    for g in gens:
        outcome = eng.submit_all(g.requests(24))
        print(f"{g.tenant.name:10s} submitted 24 requests -> {outcome}")

    snap = eng.run()
    print(f"\ndrained in {eng.ticks} ticks: {snap['total_ops']} ops, "
          f"{snap['ops_per_sec']:.0f} ops/s, "
          f"{sum(eng.batch_calls.values())} HashMem calls "
          f"({sum(eng.batch_calls.values()) / eng.ticks:.1f}/tick), "
          f"grows={eng.grow_events} compactions={eng.compact_events}")
    print(f"request latency p50={snap['request_latency_ticks']['p50']:.0f} "
          f"p99={snap['request_latency_ticks']['p99']:.0f} ticks; "
          f"occupancy mean={snap['occupancy']['mean']:.1f}/16")
    print("\nper-tenant stats:")
    print(json.dumps(reg.stats(), indent=2))


if __name__ == "__main__":
    main()
