"""Serve a small model with batched requests: continuous batching on top of
the HashMem-managed paged KV cache (pim_malloc allocation, tombstone free),
probing the page table through the performance-optimized Pallas kernel.

    PYTHONPATH=src python examples/serve_paged.py
"""
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import serve


def main():
    cfg = get_config("qwen3-8b").replace(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=8_000, vocab_pad_to=64, attn_chunk=128)
    mesh = make_mesh((1, 1), ("data", "model"))
    done, mgr, steps = serve(
        cfg, mesh, batch=4, requests=10, max_new=12, horizon=128,
        page_tokens=32, backend="perf")
    print(f"\npage-table state after drain: live={mgr.live_pages()} "
          f"free={[len(a) for a in mgr.free]}")


if __name__ == "__main__":
    main()
