"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on CPU with the full production stack (sharded data pipeline,
pjit train step, checkpointing, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.configs import get_config
from repro.configs.base import OptimConfig, ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: llama3 family scaled to 8 layers / d_model 512
    cfg = get_config("llama3-8b").replace(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32_000, vocab_pad_to=256, attn_chunk=256)
    from repro.models.model import count_params
    print(f"model: {count_params(cfg)/1e6:.1f}M params")

    shape = ShapeConfig("train", seq_len=512, global_batch=8, kind="train")
    oc = OptimConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    mesh = make_mesh((1, 1), ("data", "model"))

    _, _, losses, monitor, _ = train(
        cfg, shape, oc, mesh, num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=100, log_every=20)
    steps = sorted(losses)
    print(f"loss: {losses[steps[0]]:.3f} -> {losses[steps[-1]]:.3f} "
          f"({len(monitor.flagged)} straggler steps flagged)")


if __name__ == "__main__":
    main()
