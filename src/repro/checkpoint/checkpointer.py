"""Fault-tolerant checkpointing: atomic, integrity-checked, mesh-elastic.

  * atomic: write into ``<dir>/tmp.<step>``, fsync, rename to ``step_<n>`` —
    a crash mid-save never corrupts the latest checkpoint.
  * integrity: manifest.json stores shape/dtype/sha256 per leaf; restore
    verifies before use.
  * elastic: arrays are saved as full (host-gathered) buffers; restore takes
    a *target* sharding tree for ANY mesh shape, so a job restarted on a
    different topology (node failure -> smaller mesh) resharding is free.
  * async: save() can run in a background thread (overlaps the next step).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(p) for p in path), x) for path, x in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = False):
        self.wait()
        # gather to host synchronously (cheap view of device arrays)
        flat, _ = _flatten(tree)
        host = [(name, np.asarray(x)) for name, x in flat]

        def _write():
            tmp = self.dir / f"tmp.{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "arrays": {}}
            for name, arr in host:
                fn = hashlib.md5(name.encode()).hexdigest()[:16] + ".npy"
                np.save(tmp / fn, arr)
                manifest["arrays"][name] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
                }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            fd = os.open(tmp, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc(keep=3)

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self, keep: int):
        steps = sorted(self.all_steps())
        for s in steps[:-keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Any = None, verify: bool = True):
        """target_tree provides structure+dtype; shardings (optional pytree of
        NamedSharding) places leaves on the CURRENT mesh (elastic restore)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat, treedef = _flatten(target_tree)
        shard_flat = None
        if shardings is not None:
            sflat, _ = _flatten(shardings)
            shard_flat = dict(sflat)
        out = []
        for name, ref in flat:
            meta = manifest["arrays"][name]
            arr = np.load(d / meta["file"])
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()
                if h != meta["sha256"]:
                    raise IOError(f"checkpoint corruption in {name}")
            if list(arr.shape) != list(ref.shape):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {ref.shape}")
            if shard_flat is not None and name in shard_flat:
                out.append(jax.device_put(arr, shard_flat[name]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
