"""Architecture registry: maps ``--arch`` ids to ModelConfigs."""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    MeshConfig,
    OptimConfig,
    TrainConfig,
    ServeConfig,
    HashMemConfig,
)

_ARCH_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "internvl2-2b": "internvl2_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama3-8b": "llama3_8b",
    "qwen3-8b": "qwen3_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "xlstm-1.3b": "xlstm_1_3b",
    "whisper-tiny": "whisper_tiny",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_ARCH_MODULES)}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


# long_500k requires sub-quadratic attention (DESIGN.md §Arch-applicability):
# hybrid (jamba: 1/8 attention + paged KV), SWA (h2o-danube: bounded window),
# ssm (xlstm: O(1) recurrent state).  Pure full-attention archs skip it.
LONG_CONTEXT_ARCHS = ("jamba-v0.1-52b", "h2o-danube-1.8b", "xlstm-1.3b")


def cells(include_long: bool = True):
    """All assigned (arch x shape) cells. 40 assigned; 33 runnable (7 long_500k
    skips for pure full-attention archs, recorded in DESIGN.md)."""
    out = []
    for arch in ARCHS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            out.append((arch, shape))
    return out


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    c = get_config(arch)
    kw = dict(
        num_layers=min(c.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(c.num_kv_heads, 4) if c.num_kv_heads < c.num_heads else 4,
        head_dim=32,
        d_ff=0 if c.d_ff == 0 else 256,
        vocab_size=512,
        vocab_pad_to=64,
        attn_chunk=64,
        mamba_chunk=16,
        mlstm_chunk=16,
    )
    if c.num_experts:
        kw.update(num_experts=8, top_k=min(c.top_k, 4))
    if c.d_ff_dense:
        kw.update(d_ff_dense=256)
    if c.is_encoder_decoder:
        kw.update(num_encoder_layers=2, num_layers=2)
    if c.num_prefix_embeds:
        kw.update(num_prefix_embeds=8)
    if c.slstm_every:
        kw.update(slstm_every=2)
    if c.attn_every > 1:
        kw.update(attn_every=4, attn_offset=2)
    if c.sliding_window:
        kw.update(sliding_window=64)
    return c.replace(**kw)
