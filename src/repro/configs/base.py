"""Config dataclasses for the repro framework.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the registry in ``repro.configs.__init__`` maps ``--arch``
ids to them.  Shapes (the 4 assigned input-shape regimes) are global and live
in ``SHAPES`` below.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (exact assigned values; see configs/<id>.py)."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 1
    d_ff_dense: int = 0              # FFN width of interleaved dense layers (0 = d_ff)
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    moe_impl: str = "gspmd"          # gspmd (global dispatch, baseline) |
                                     # ep (shard_map expert-parallel all_to_all)

    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    qk_norm: bool = False
    attn_every: int = 1              # hybrid: layer i is attention iff i % attn_every == attn_offset
    attn_offset: int = 0             # (else SSM block); attn_every=1 -> all attention

    # --- SSM (mamba) ---
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model/16)

    # --- xLSTM ---
    slstm_every: int = 0             # >0: layer i is sLSTM iff i % slstm_every == 0 (else mLSTM)

    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | patch_stub | audio_stub
    num_prefix_embeds: int = 0       # vlm: number of precomputed patch embeddings

    # --- numerics / misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    vocab_pad_to: int = 256          # pad vocab for clean lane/shard divisibility
    remat: bool = True               # activation checkpointing per block
    scan_layers: bool = True         # lax.scan over stacked layer params
    inner_unroll: bool = False       # unroll inner chunk scans (cost probes:
                                     # XLA HloCostAnalysis counts a while-loop
                                     # body ONCE; probes unroll to get true FLOPs)
    mlstm_unroll: bool = True        # allow inner_unroll to expand the mLSTM
                                     # chunk scan (False for xlstm probes: the
                                     # unrolled bwd HLO is intractable to
                                     # compile; roofline.py adds the analytic
                                     # per-chunk correction instead)
    attn_chunk: int = 1024           # kv-chunk size for flash-style chunked attention
    mamba_chunk: int = 64            # chunk length for the chunked selective scan
    mlstm_chunk: int = 64            # chunk length for chunked mLSTM
    mlstm_scan_groups: int = 0       # >0: two-level sqrt-remat over mLSTM
                                     # chunks (saves G outer states, recomputes
                                     # inner chunk states in bwd)

    # source citation for the exact numbers (required by the assignment)
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def d_inner(self) -> int:        # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, (self.d_model + 15) // 16)

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and (i % self.moe_every == self.moe_offset % self.moe_every)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        return i % self.attn_every == self.attn_offset

    def is_slstm_layer(self, i: int) -> bool:
        return self.slstm_every > 0 and i % self.slstm_every == 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter counting (used for 6ND model flops and EXPERIMENTS.md) ---
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; see tests)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Shapes (assigned shape regimes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    # decode shapes: seq_len is the *KV horizon*, one new token is generated.


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # production: single pod (16,16) ("data","model"); multi-pod (2,16,16)
    # ("pod","data","model").  Overridable for tests.
    shape: Optional[Tuple[int, ...]] = None
    axis_names: Optional[Tuple[str, ...]] = None

    def resolved(self) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
        if self.shape is not None:
            return tuple(self.shape), tuple(self.axis_names)
        if self.multi_pod:
            return (2, 16, 16), ("pod", "data", "model")
        return (16, 16), ("data", "model")


# ---------------------------------------------------------------------------
# Training / serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # "bfloat16" halves optimizer memory (400B configs)


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    shape: ShapeConfig
    optim: OptimConfig = field(default_factory=OptimConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    # fault tolerance knobs
    max_restarts: int = 3
    straggler_deadline_s: float = 0.0   # 0 = disabled
    grad_compression: str = "none"      # none | bf16 | int8_ef


@dataclass(frozen=True)
class ServeConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    kv_page_tokens: int = 2048          # tokens per KV page (bucket-per-page)
    max_pages_per_seq: int = 0          # 0 -> derived from shape.seq_len
    kv_dtype: str = "bfloat16"

    @property
    def pages_per_seq(self) -> int:
        if self.max_pages_per_seq:
            return self.max_pages_per_seq
        return (self.shape.seq_len + self.kv_page_tokens - 1) // self.kv_page_tokens


# ---------------------------------------------------------------------------
# HashMem (the paper's own workload, Table 1/2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HashMemConfig:
    """Configuration of the HashMem structure itself (paper Table 1/2)."""

    num_buckets: int = 1 << 15
    slots_per_page: int = 512        # paper: 512-2048 columns per subarray row
    key_bits: int = 32               # paper evaluates 32-bit keys; 4/8/16 supported
    overflow_pages: int = 1 << 14    # pool for chained pages (pim_malloc arena)
    hash_fn: str = "murmur3_fmix"    # murmur3_fmix | mult_shift | identity
    salt: int = 0x9E3779B9
    backend: str = "perf"            # ref | area | perf | bitserial
    max_chain: int = 8               # static probe chain bound (RLU command depth)

    # --- online mutation engine (grow/compact; hashmap.py docstring) ---
    auto_grow: bool = True           # arena exhaustion triggers resize instead
                                     # of dropped writes (insert_auto)
    growth_factor: int = 2           # buckets/overflow scale per grow()
    resize: str = "rebuild"          # "rebuild": grow() = stop-the-world
                                     # rehash-rebuild of the whole table;
                                     # "extendible": directory-based
                                     # extendible hashing (Dash) — an
                                     # overflowing bucket group splits alone
                                     # (one new page row written), the
                                     # directory doubles by pointer copy,
                                     # every other group stays probe-able.
                                     # Requires pow2 num_buckets; excludes
                                     # displacement (hashmap.create checks)
    max_load_factor: float = 0.85    # proactive-grow threshold (live / slots)
    compact_tombstone_frac: float = 0.25  # compact() when tombstones exceed
                                          # this fraction of total slots
    compact_chain_len: int = 0       # >0: serving-layer compaction also fires
                                     # when any bucket chain exceeds this many
                                     # pages while tombstones exist (skewed
                                     # delete streams pile tombstoned pages on
                                     # hot chains long before the global
                                     # tombstone fraction trips)

    # --- fingerprint lane + displacement/stash (Dash / IcebergHT) ---
    fingerprint_bits: int = 0        # >0: per-slot fingerprint bit-planes;
                                     # probes activate only fp-matching rows
    displacement: bool = False       # insert tries the H2 bucket's direct
                                     # page before chaining at H1; residue
                                     # falls into the stash
    stash_slots: int = 0             # per-table stash entries absorbing
                                     # inserts both buckets reject

    @property
    def num_pages(self) -> int:
        return self.num_buckets + self.overflow_pages


# Paper microbenchmark: 100M uint32->uint32 pairs, 10M random probes
# (section 4.1.1).  Scaled default for the CPU container; --full restores it.
PAPER_WORKLOAD = {
    "num_pairs": 100_000_000,
    "probe_fraction": 0.10,
    "key_bytes": 4,
    "value_bytes": 4,
}
