"""The paper's own workload config (HashMem §4, Tables 1-2).

DDR4_8Gb_x16_3200 single channel, 8 banks/rank, 128 subarrays/bank,
512 rows/subarray; microbenchmark = 100M uint32->uint32 pairs (800 MB),
10M random probes.  The CPU container default is scaled to 2^22 pairs;
``--full`` in the benchmark harness restores the paper scale.
"""
from repro.configs.base import HashMemConfig, PAPER_WORKLOAD

# Structure sized so that the paper's 100M pairs fit at the paper's load factor:
# 2^18 buckets x 512 slots/page = 134M direct slots (+ overflow arena).
PAPER_HASHMEM = HashMemConfig(
    num_buckets=1 << 18,
    slots_per_page=512,
    key_bits=32,
    overflow_pages=1 << 16,
    hash_fn="murmur3_fmix",
    backend="perf",
    max_chain=8,
)

# Scaled default used by tests/benchmarks on this CPU container.
SCALED_HASHMEM = HashMemConfig(
    num_buckets=1 << 12,
    slots_per_page=512,
    key_bits=32,
    overflow_pages=1 << 10,
    hash_fn="murmur3_fmix",
    backend="perf",
    max_chain=8,
)

WORKLOAD = dict(PAPER_WORKLOAD)

# DDR4-3200 timing parameters used by the analytic model (benchmarks/timing_model.py)
# sourced from the DDR4 JEDEC spec values used by DRAMsim3 [7] for
# DDR4_8Gb_x16_3200; all in nanoseconds.
DDR4_TIMING = {
    "tCK": 0.625,        # clock period (ns) @ 1600 MHz (DDR-3200)
    "tRCD": 13.75,       # row activate -> column access
    "tRP": 13.75,        # precharge
    "tRAS": 32.0,        # row active time
    "tCAS": 13.75,       # column access strobe (CL22 * tCK)
    "tCCD_S": 2.5,       # column-to-column (short)
    "burst_ns": 2.5,     # BL8 transfer time
    "row_bytes": 1024,   # 8Kb row per x16 device... modeled at rank level: 8KB
    "rank_row_bytes": 8192,
    "channel_gbps": 25.6,  # DDR4-3200 single channel peak
}
