"""internvl2-2b — VLM: InternViT frontend (stub) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf OpenGVLab/InternVL2-2B]  Assigned config:
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The vision frontend is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings that are prepended to the token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,   # InternLM2 long-context rope base
    frontend="patch_stub",
    num_prefix_embeds=256,
    source="arXiv:2404.16821 (InternVL2); hf:OpenGVLab/InternVL2-2B",
)
