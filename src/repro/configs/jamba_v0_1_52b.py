"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf ai21labs/Jamba-v0.1]  Assigned config:
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Jamba block structure: in every 8-layer block exactly one attention layer
(position 4), the rest Mamba; MoE replaces the MLP on every other layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    rope_theta=10_000.0,     # Jamba attention layers use no explicit RoPE scaling
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    source="arXiv:2403.19887 (Jamba); hf:ai21labs/Jamba-v0.1",
)
