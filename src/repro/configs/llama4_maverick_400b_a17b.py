"""llama4-maverick-400b-a17b — MoE 128e top-1 with interleaved dense layers.

[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]  Assigned config:
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.
Llama-4 style: MoE on every other layer (expert d_ff=8192 + 1 shared expert),
dense SwiGLU (d_ff=16384) on the rest; early-fusion multimodal is out of the
assigned backbone scope.  ~400B total / ~17B active parameters.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,               # expert width
    d_ff_dense=16384,        # interleaved dense-layer width
    vocab_size=202048,
    num_experts=128,
    top_k=1,
    moe_every=2,
    moe_offset=1,
    num_shared_experts=1,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family); unverified",
)
