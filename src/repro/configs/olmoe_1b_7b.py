"""olmoe-1b-7b — fully MoE LM: 64 experts, top-8, fine-grained d_ff=1024.

[arXiv:2409.02060; hf allenai/OLMoE-1B-7B-0924]  Assigned config:
16L d_model=2048 16H (GQA kv=16 -> MHA) d_ff=1024 vocab=50304,
MoE 64e top-8 on every layer.  ~1B active / ~7B total.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    top_k=8,
    moe_every=1,
    moe_offset=0,
    rope_theta=10_000.0,
    qk_norm=True,            # OLMoE uses QK-norm
    source="arXiv:2409.02060 (OLMoE); hf:allenai/OLMoE-1B-7B-0924",
)
