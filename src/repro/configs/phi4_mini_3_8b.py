"""phi4-mini-3.8b — dense GQA transformer, RoPE + SwiGLU, 200k vocab.

[arXiv:2412.08905; hf microsoft/Phi-4-mini-instruct]  Assigned config:
32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,     # phi-4-mini ties the LM head
    rope_theta=10_000.0,
    source="arXiv:2412.08905 (Phi-4); hf:microsoft/Phi-4-mini-instruct",
)
