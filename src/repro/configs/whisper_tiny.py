"""whisper-tiny — encoder-decoder ASR transformer; conv frontend is a STUB.

[arXiv:2212.04356; unverified]  Assigned config:
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865, enc-dec.
Per the assignment the audio conv frontend is a stub: input_specs() provides
precomputed frame embeddings (B, frames, d_model) for the encoder; the decoder
is a standard causal transformer with cross-attention.
Decode shapes exercise the DECODER step (32k self-KV horizon is mechanical —
beyond Whisper's trained 448-token horizon; shapes are the contract).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,                # decoder layers
    num_encoder_layers=4,
    is_encoder_decoder=True,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    frontend="audio_stub",
    tie_embeddings=True,         # whisper ties the decoder embedding
    rope_theta=10_000.0,         # repro uses RoPE in the decoder (sinusoidal in paper)
    source="arXiv:2212.04356 (Whisper); unverified",
)
