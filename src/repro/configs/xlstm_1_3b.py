"""xlstm-1.3b — recurrent xLSTM LM: sLSTM + mLSTM blocks (1:7).

[arXiv:2405.04517; unverified]  Assigned config:
48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.
d_ff=0: xLSTM blocks carry their own up/down projections; there is no separate
FFN sub-block.  Every 8th layer is sLSTM (scalar memory, strictly sequential),
the rest mLSTM (matrix memory, chunkwise-parallel).  head_dim = 2048/4 = 512.
Attention-free -> the long_500k decode shape RUNS for this arch (O(1) state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    source="arXiv:2405.04517 (xLSTM); unverified",
)
