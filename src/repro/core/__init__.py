"""HashMem core: the paper's contribution as a composable JAX module."""
from repro.core.hashing import (
    EMPTY_KEY, TOMBSTONE_KEY, MAX_USER_KEY, hash_to_bucket, HASH_FNS,
)
from repro.core.hashmap import (
    HashMem, create, build, build_check, insert, probe, delete,
    resolve_pages, stats,
)
