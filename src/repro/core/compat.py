"""jax version-compat shims.

The repo targets the current jax API surface; older releases (0.4.x) spell
some of it differently.  Centralizing the fallbacks here keeps call sites on
the modern spelling:

  * ``shard_map`` — new jax exposes ``jax.shard_map`` with ``check_vma``;
    0.4.x has ``jax.experimental.shard_map.shard_map`` with ``check_rep``.
  * mesh construction with axis_types lives in ``repro.launch.mesh``.
"""
from __future__ import annotations

import jax

# Newer jax defaults to partitionable threefry, making jax.random output
# independent of the output sharding — the repo's distributed parity code
# (same init on every mesh) assumes it.  0.4.x still defaults to False,
# where jitted sharded init draws DIFFERENT values per mesh shape; adopt the
# modern behavior unless the user pinned the flag themselves (env var or an
# explicit jax.config.update before importing repro).
import os as _os

if (not jax.config.jax_threefry_partitionable
        and "JAX_THREEFRY_PARTITIONABLE" not in _os.environ):
    jax.config.update("jax_threefry_partitionable", True)

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(name):
        # psum of 1 over the axis == its size; constant-folded by XLA
        return jax.lax.psum(1, name)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma,
                                 **kw)
