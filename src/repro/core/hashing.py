"""Hash-function family for HashMem (paper §2.5, §6 'Hash Function').

All hashes operate on uint32 keys and return uint32 hashes; bucket selection
is ``hash % num_buckets``.  uint32 arithmetic in JAX wraps (defined overflow),
which is exactly what these mixers rely on.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32

# Sentinels: user keys must be < 0xFFFFFFFE (enforced by callers/tests).
EMPTY_KEY = jnp.uint32(0xFFFFFFFF)
TOMBSTONE_KEY = jnp.uint32(0xFFFFFFFE)
MAX_USER_KEY = 0xFFFFFFFD


def murmur3_fmix(keys, salt: int = 0x9E3779B9):
    """Murmur3 32-bit finalizer (full avalanche)."""
    h = keys.astype(U32) ^ U32(salt)
    h = h ^ (h >> 16)
    h = h * U32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * U32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def mult_shift(keys, salt: int = 0x9E3779B9):
    """Knuth multiplicative hash (weaker; exercises paper's Fig. 4 skew)."""
    h = keys.astype(U32) * U32(2654435761)
    return h ^ U32(salt)


def identity(keys, salt: int = 0):
    del salt
    return keys.astype(U32)


HASH_FNS = {
    "murmur3_fmix": murmur3_fmix,
    "mult_shift": mult_shift,
    "identity": identity,
}


def hash_to_bucket(keys, num_buckets: int, fn: str = "murmur3_fmix", salt: int = 0x9E3779B9):
    """keys (…,) uint32 -> bucket ids (…,) int32 in [0, num_buckets)."""
    h = HASH_FNS[fn](keys, salt)
    return (h % U32(num_buckets)).astype(jnp.int32)
