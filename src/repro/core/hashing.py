"""Hash-function family for HashMem (paper §2.5, §6 'Hash Function').

All hashes operate on uint32 keys and return uint32 hashes; bucket selection
is ``hash % num_buckets``.  uint32 arithmetic in JAX wraps (defined overflow),
which is exactly what these mixers rely on.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32

# Sentinels: user keys must be < 0xFFFFFFFE (enforced by callers/tests).
EMPTY_KEY = jnp.uint32(0xFFFFFFFF)
TOMBSTONE_KEY = jnp.uint32(0xFFFFFFFE)
MAX_USER_KEY = 0xFFFFFFFD


def murmur3_fmix(keys, salt: int = 0x9E3779B9):
    """Murmur3 32-bit finalizer (full avalanche)."""
    h = keys.astype(U32) ^ U32(salt)
    h = h ^ (h >> 16)
    h = h * U32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * U32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def mult_shift(keys, salt: int = 0x9E3779B9):
    """Knuth multiplicative hash (weaker; exercises paper's Fig. 4 skew)."""
    h = keys.astype(U32) * U32(2654435761)
    return h ^ U32(salt)


def identity(keys, salt: int = 0):
    del salt
    return keys.astype(U32)


HASH_FNS = {
    "murmur3_fmix": murmur3_fmix,
    "mult_shift": mult_shift,
    "identity": identity,
}


def hash_to_bucket(keys, num_buckets: int, fn: str = "murmur3_fmix", salt: int = 0x9E3779B9):
    """keys (…,) uint32 -> bucket ids (…,) int32 in [0, num_buckets)."""
    h = HASH_FNS[fn](keys, salt)
    return (h % U32(num_buckets)).astype(jnp.int32)


def bits_used(num_buckets: int) -> int:
    """Exact log2 of a power-of-two directory size (extendible hashing's
    global depth).  With ``num_buckets = 2**d`` the modulo in
    :func:`hash_to_bucket` IS the low-``d``-bits prefix, so the existing
    bucket id doubles as the directory index."""
    d = num_buckets.bit_length() - 1
    if num_buckets <= 0 or (1 << d) != num_buckets:
        raise ValueError(
            f"extendible resize needs a power-of-two directory; "
            f"num_buckets={num_buckets} is not")
    return d


def hash_prefix(keys, depth: int, fn: str = "murmur3_fmix",
                salt: int = 0x9E3779B9):
    """Low-``depth``-bits hash prefix, int32 — the extendible-hashing bucket
    resolution: at local depth ``ld`` every key of a group shares
    ``hash_prefix(key, ld)``, and a split separates them on bit ``ld``."""
    h = HASH_FNS[fn](keys, salt)
    return (h & U32((1 << depth) - 1)).astype(jnp.int32)


# Keys at or above this floor are reserved: ROUTE_PAD (0xFFFFFFF0, routing
# padding — rlu.py), and the EMPTY/TOMBSTONE sentinels at the top.
RESERVED_KEY_FLOOR = 0xFFFFFFF0


def validate_user_keys(keys, where: str = "insert"):
    """Raise ValueError if any key collides with the reserved pad/sentinel
    range [0xFFFFFFF0, 0xFFFFFFFF].  A stored key up there would silently
    become routing padding or an empty/tombstone marker.  Shared by the
    serving admission path and the decode-mode page-table allocator."""
    keys = np.asarray(keys)
    if keys.size and int(keys.max()) >= RESERVED_KEY_FLOOR:
        bad = int(keys[keys >= RESERVED_KEY_FLOOR][0])
        raise ValueError(
            f"{where} key {bad:#x} collides with the reserved pad/sentinel "
            f"range [{RESERVED_KEY_FLOOR:#x}, 0xffffffff]")


# Fixed salts for the fingerprint lane and the second (displacement) bucket
# choice.  FP_SALT is independent of the table salt so the fingerprint of a
# key is a pure function of (key, fp_bits) — PageStore can recompute it
# without knowing the table config.  B2_SALT is XOR-folded into the table
# salt so H2 stays decorrelated from H1 under any configured salt.
FP_SALT = 0x7FEB352D
B2_SALT = 0x68E31DA4


def fingerprint(keys, fp_bits: int):
    """keys (…,) uint32 -> low ``fp_bits`` of a salted murmur mix, uint32.

    Deliberately NOT the bucket hash: a whole bucket shares hash%B, so
    fingerprints must come from an independent mix or every key in a page
    would collide.
    """
    return murmur3_fmix(keys, FP_SALT) & U32((1 << fp_bits) - 1)


def hash_to_bucket2(keys, num_buckets: int, fn: str = "murmur3_fmix",
                    salt: int = 0x9E3779B9):
    """Second bucket choice for displacement inserts (IcebergHT H2).

    Same contract as :func:`hash_to_bucket`.  Note the ``identity`` hash fn
    ignores its salt, so H2 degenerates to H1 there — displacement then
    adds nothing but stays correct (round 2 chains at the same bucket).
    """
    h = HASH_FNS[fn](keys, (salt ^ B2_SALT) & 0xFFFFFFFF)
    return (h % U32(num_buckets)).astype(jnp.int32)
