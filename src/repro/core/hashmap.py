"""Functional HashMem structure (paper §2.4-2.5, §3).

Semantics mirror the paper exactly:

  * bucket i owns page i (bucket-per-row mapping); overflow pages are chained
    through ``page_next`` — the paper's "bookkeeping structure ... attaches and
    links new page to old page in a Linked List fashion".
  * ``free_top`` is the ``pim_malloc`` bump allocator over the overflow arena.
  * deletion writes TOMBSTONE_KEY "at the cost of wasted space" (paper §2.5):
    tombstoned slots are NOT reused; inserts append at the chain tail.
  * probing resolves the page chain (the RLU command stream) and hands the
    page list to a backend (ref / area / perf / bitserial — see probe.py and
    kernels/).

Everything is a JAX pytree and jit/vmap/pjit-compatible; the structure is
immutable — every mutation returns a new HashMem.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import HashMemConfig
from repro.core import layout
from repro.core.hashing import EMPTY_KEY, TOMBSTONE_KEY, hash_to_bucket

I32 = jnp.int32
U32 = jnp.uint32


@partial(jax.tree_util.register_dataclass,
         data_fields=["key_pages", "val_pages", "planes", "bucket_head",
                      "page_next", "page_fill", "free_top"],
         meta_fields=["config"])
@dataclass
class HashMem:
    key_pages: jax.Array          # (num_pages, slots) uint32
    val_pages: jax.Array          # (num_pages, slots) uint32
    planes: Optional[jax.Array]   # (num_pages, key_bits, slots//32) uint32 | None
    bucket_head: jax.Array        # (num_buckets,) int32
    page_next: jax.Array          # (num_pages,) int32, -1 terminal
    page_fill: jax.Array          # (num_pages,) int32 (high-water mark incl. tombstones)
    free_top: jax.Array           # () int32 pim_malloc bump pointer
    config: HashMemConfig


def _keep_planes(cfg: HashMemConfig) -> bool:
    return cfg.backend == "bitserial"


def create(cfg: HashMemConfig) -> HashMem:
    """Empty HashMem: every bucket pre-owns its direct page (paper §2.4)."""
    keys, vals = layout.empty_pool(cfg.num_pages, cfg.slots_per_page)
    planes = layout.pack_bitplanes(keys, cfg.key_bits) if _keep_planes(cfg) else None
    return HashMem(
        key_pages=keys,
        val_pages=vals,
        planes=planes,
        bucket_head=jnp.arange(cfg.num_buckets, dtype=I32),
        page_next=jnp.full((cfg.num_pages,), -1, dtype=I32),
        page_fill=jnp.zeros((cfg.num_pages,), dtype=I32),
        free_top=jnp.asarray(cfg.num_buckets, dtype=I32),
        config=cfg,
    )


# ---------------------------------------------------------------------------
# Bulk build (vectorized; the paper populates the dataset before probing)
# ---------------------------------------------------------------------------

def build(cfg: HashMemConfig, keys: jax.Array, vals: jax.Array) -> HashMem:
    """Vectorized bulk load of N key/value pairs.

    Buckets receive ceil(count/slots) pages; overflow pages are allocated
    contiguously from the arena in bucket order.  Duplicate keys are all
    stored; probe returns the first match in chain order.
    """
    b = hash_to_bucket(keys.astype(U32), cfg.num_buckets, cfg.hash_fn, cfg.salt)
    return build_with_buckets(cfg, keys, vals, b)


def build_with_buckets(cfg: HashMemConfig, keys: jax.Array, vals: jax.Array,
                       b: jax.Array) -> HashMem:
    """Bulk load with caller-supplied bucket ids (used by the RLU channel
    layer, which derives (owner shard, local bucket) from one global hash)."""
    cfg_slots = cfg.slots_per_page
    n = keys.shape[0]
    keys = keys.astype(U32)
    vals = vals.astype(U32)
    order = jnp.argsort(b)
    bs, ks, vs = b[order], keys[order], vals[order]

    start = jnp.searchsorted(bs, bs, side="left")
    rank = jnp.arange(n, dtype=I32) - start.astype(I32)                    # rank in bucket
    depth = rank // cfg_slots
    slot = rank % cfg_slots

    counts = jnp.zeros((cfg.num_buckets,), I32).at[bs].add(1)
    n_over = jnp.maximum((counts + cfg_slots - 1) // cfg_slots - 1, 0)     # overflow pages/bucket
    over_off = jnp.cumsum(n_over) - n_over                                 # exclusive prefix

    page = jnp.where(depth == 0, bs,
                     cfg.num_buckets + over_off[bs] + depth - 1).astype(I32)

    key_pages, val_pages = layout.empty_pool(cfg.num_pages, cfg_slots)
    key_pages = key_pages.at[page, slot].set(ks)
    val_pages = val_pages.at[page, slot].set(vs)
    page_fill = jnp.zeros((cfg.num_pages,), I32).at[page].max(slot + 1)

    # chain links: first element landing on a depth>=1 page links prev -> page
    is_link = (depth >= 1) & (slot == 0)
    prev_page = jnp.where(depth == 1, bs,
                          cfg.num_buckets + over_off[bs] + depth - 2).astype(I32)
    link_idx = jnp.where(is_link, prev_page, cfg.num_pages)                # OOB -> dropped
    page_next = jnp.full((cfg.num_pages,), -1, I32).at[link_idx].set(page, mode="drop")

    free_top = cfg.num_buckets + jnp.sum(n_over)
    planes = layout.pack_bitplanes(key_pages, cfg.key_bits) if _keep_planes(cfg) else None

    return HashMem(key_pages=key_pages, val_pages=val_pages, planes=planes,
                   bucket_head=jnp.arange(cfg.num_buckets, dtype=I32),
                   page_next=page_next, page_fill=page_fill,
                   free_top=free_top.astype(I32), config=cfg)


def build_check(cfg: HashMemConfig, keys) -> dict:
    """Pre-flight (non-jit) checks that the arena/chain bounds suffice."""
    import numpy as np
    b = np.asarray(hash_to_bucket(jnp.asarray(keys, U32), cfg.num_buckets,
                                  cfg.hash_fn, cfg.salt))
    counts = np.bincount(b, minlength=cfg.num_buckets)
    pages = np.maximum((counts + cfg.slots_per_page - 1) // cfg.slots_per_page, 0)
    return {
        "max_chain_needed": int(pages.max(initial=0)),
        "overflow_pages_needed": int(np.maximum(pages - 1, 0).sum()),
        "fits": bool(pages.max(initial=0) <= cfg.max_chain
                     and np.maximum(pages - 1, 0).sum() <= cfg.overflow_pages),
        "load_factor": float(counts.sum() / (cfg.num_pages * cfg.slots_per_page)),
        "bucket_counts": counts,
    }


# ---------------------------------------------------------------------------
# RLU command-stream resolution (paper §2.3: RLU locates subarray rows)
# ---------------------------------------------------------------------------

def resolve_pages(hm: HashMem, queries: jax.Array) -> jax.Array:
    """queries (Q,) uint32 -> (Q, max_chain) int32 page ids, -1 padded.

    This is the RLU step: translate each probe key into the ordered list of
    subarray rows (pages) to activate.  Bounded by config.max_chain.
    """
    cfg = hm.config
    b = hash_to_bucket(queries.astype(U32), cfg.num_buckets, cfg.hash_fn, cfg.salt)
    return resolve_pages_by_bucket(hm, b)


def resolve_pages_by_bucket(hm: HashMem, b: jax.Array) -> jax.Array:
    cfg = hm.config
    page = hm.bucket_head[b]                                              # (Q,)
    cols = [page]
    for _ in range(cfg.max_chain - 1):
        nxt = jnp.where(page >= 0, hm.page_next[jnp.maximum(page, 0)], -1)
        cols.append(nxt)
        page = nxt
    return jnp.stack(cols, axis=1).astype(I32)


# ---------------------------------------------------------------------------
# Probe / insert / delete
# ---------------------------------------------------------------------------

def probe(hm: HashMem, queries: jax.Array, backend: Optional[str] = None):
    """Batched probe.  Returns (values (Q,) uint32, found (Q,) bool)."""
    from repro.core.probe import probe_pages   # local import to avoid cycle
    pages = resolve_pages(hm, queries)
    return probe_pages(hm, queries.astype(U32), pages,
                       backend=backend or hm.config.backend)


def _write_key_bits(planes, page, slot, key, key_bits: int):
    """Incremental bit-plane maintenance for a single (page, slot) write."""
    word = slot // 32
    bit = (slot % 32).astype(U32)
    j = jnp.arange(key_bits, dtype=U32)
    kbits = ((key.astype(U32) >> j) & U32(1))                              # (b,)
    old = planes[page, :, word]                                           # (b,)
    mask = ~(U32(1) << bit)
    new = (old & mask) | (kbits << bit)
    return planes.at[page, :, word].set(new)


def insert(hm: HashMem, keys: jax.Array, vals: jax.Array):
    """Batched insert (paper §3.1 Listing 1), sequential within the batch so
    intra-batch bucket collisions resolve exactly like repeated single inserts.

    Returns (new_hm, ok (B,) bool).  ok=False iff pim_malloc failed
    (PR_ERROR: arena exhausted or chain bound exceeded).
    """
    cfg = hm.config
    slots = cfg.slots_per_page

    def step(state, kv):
        key_pages, val_pages, planes, page_next, page_fill, free_top = state
        k, v = kv
        b = hash_to_bucket(k[None], cfg.num_buckets, cfg.hash_fn, cfg.salt)[0]
        # walk to chain tail (bounded)
        last = hm.bucket_head[b]
        for _ in range(cfg.max_chain - 1):
            nxt = page_next[jnp.maximum(last, 0)]
            last = jnp.where(nxt >= 0, nxt, last)
        fill = page_fill[last]
        need_new = fill >= slots
        new_page = free_top
        ok = jnp.where(need_new, new_page < cfg.num_pages, True)
        tp = jnp.where(need_new, new_page, last).astype(I32)
        ts = jnp.where(need_new, 0, fill).astype(I32)
        wp = jnp.where(ok, tp, cfg.num_pages)                              # OOB drop if !ok
        key_pages = key_pages.at[wp, ts].set(k, mode="drop")
        val_pages = val_pages.at[wp, ts].set(v, mode="drop")
        if planes is not None:
            planes = jnp.where(ok, _write_key_bits(planes, tp, ts, k, cfg.key_bits), planes)
        page_fill = page_fill.at[wp].set(ts + 1, mode="drop")
        do_link = need_new & ok
        page_next = page_next.at[jnp.where(do_link, last, cfg.num_pages)].set(
            new_page, mode="drop")
        free_top = free_top + do_link.astype(I32)
        return (key_pages, val_pages, planes, page_next, page_fill, free_top), ok

    init = (hm.key_pages, hm.val_pages, hm.planes, hm.page_next, hm.page_fill,
            hm.free_top)
    (kp, vp, pl, pn, pf, ft), oks = jax.lax.scan(
        step, init, (keys.astype(U32), vals.astype(U32)))
    new = HashMem(key_pages=kp, val_pages=vp, planes=pl,
                  bucket_head=hm.bucket_head, page_next=pn, page_fill=pf,
                  free_top=ft, config=cfg)
    return new, oks


def delete(hm: HashMem, keys: jax.Array):
    """Batched tombstone delete (paper §2.5).  Returns (new_hm, found)."""
    cfg = hm.config
    slots = cfg.slots_per_page
    q = keys.astype(U32)
    pages = resolve_pages(hm, q)                                           # (Q, C)
    rows = hm.key_pages[jnp.maximum(pages, 0)]                             # (Q, C, S)
    match = (rows == q[:, None, None]) & (pages >= 0)[:, :, None]
    qn, C = pages.shape
    flat = match.reshape(qn, C * slots)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)
    c, s = idx // slots, (idx % slots).astype(I32)
    pg = pages[jnp.arange(qn), c]
    wp = jnp.where(found, pg, cfg.num_pages)                               # OOB drop
    key_pages = hm.key_pages.at[wp, s].set(TOMBSTONE_KEY, mode="drop")
    planes = hm.planes
    if planes is not None:
        def one(pl, args):
            f, p, sl = args
            return jnp.where(
                f, _write_key_bits(pl, p, sl, TOMBSTONE_KEY, cfg.key_bits), pl), None
        planes, _ = jax.lax.scan(one, planes, (found, jnp.maximum(pg, 0), s))
    new = HashMem(key_pages=key_pages, val_pages=hm.val_pages, planes=planes,
                  bucket_head=hm.bucket_head, page_next=hm.page_next,
                  page_fill=hm.page_fill, free_top=hm.free_top, config=cfg)
    return new, found


# ---------------------------------------------------------------------------
# Introspection (fig. 4 reproduction + invariants for property tests)
# ---------------------------------------------------------------------------

def stats(hm: HashMem) -> dict:
    import numpy as np
    cfg = hm.config
    kp = np.asarray(hm.key_pages)
    fill = np.asarray(hm.page_fill)
    nxt = np.asarray(hm.page_next)
    live = (kp != np.uint32(0xFFFFFFFF)) & (kp != np.uint32(0xFFFFFFFE))
    chain_len = np.zeros(cfg.num_buckets, np.int32)
    head = np.asarray(hm.bucket_head)
    for bkt in range(cfg.num_buckets):
        p, n_ = head[bkt], 0
        while p >= 0 and n_ <= cfg.max_chain:
            n_ += 1
            p = nxt[p]
        chain_len[bkt] = n_
    return {
        "live_entries": int(live.sum()),
        "tombstones": int((kp == np.uint32(0xFFFFFFFE)).sum()),
        "pages_used": int(np.sum(fill > 0)),
        "free_pages": int(cfg.num_pages - np.asarray(hm.free_top)),
        "chain_lengths": chain_len,
        "max_chain": int(chain_len.max(initial=0)),
    }
