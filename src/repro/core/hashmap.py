"""Functional HashMem structure (paper §2.4-2.5, §3).

Semantics mirror the paper exactly:

  * bucket i owns page i (bucket-per-row mapping); overflow pages are chained
    through ``page_next`` — the paper's "bookkeeping structure ... attaches and
    links new page to old page in a Linked List fashion".
  * ``free_top`` is the ``pim_malloc`` bump allocator over the overflow arena.
  * deletion writes TOMBSTONE_KEY "at the cost of wasted space" (paper §2.5):
    tombstoned slots are NOT reused; inserts append at the chain tail.
  * probing resolves the page chain (the RLU command stream) and hands the
    page list to a backend (ref / area / perf / bitserial — see probe.py and
    kernels/).

Storage layout
--------------
The structure is a thin shell around a :class:`repro.core.layout.PageStore`:
one interleaved ``(num_pages, slots, 2)`` uint32 pool (lane 0 = key,
lane 1 = value) plus the chain links, fill marks, bit-planes and the
pim_malloc pointer.  One page == one DRAM row holding keys AND values, so

  * every probe backend reads key and value from the SAME activated row —
    one page fetch per chain step (the paper's row-buffer semantics), and
  * every mutation writes key+value with ONE fused pool scatter
    (``store.write_slots``) instead of the split layout's two.

``hm.key_pages`` / ``hm.val_pages`` / ``hm.planes`` / ``hm.page_next`` /
``hm.page_fill`` / ``hm.free_top`` remain available as thin views so
external callers and the differential harness see the same split API.

Everything is a JAX pytree and jit/vmap/pjit-compatible; the structure is
immutable — every mutation returns a new HashMem.

Mutation & resizing semantics
-----------------------------
The online mutation engine extends the paper's populate-once model:

  * ``insert`` is VECTORIZED: the whole batch is resolved with the same
    sort/rank/segment machinery as ``build_with_buckets`` and appended to the
    existing chain tails in one shot — three pool-shaped scatters total
    (fused key/value write, fill high-water, chain link).  Within a batch it
    is equivalent to repeated single inserts in batch order (stable sort
    keeps intra-bucket batch order; duplicates are all stored, probe returns
    the oldest).  The original sequential version is kept as ``insert_scan``
    (reference semantics + benchmark baseline).
  * ``ok=False`` now means the element was NOT stored because pim_malloc
    failed — either the overflow arena is exhausted or appending would push
    the bucket's chain past ``config.max_chain`` (the RLU command-depth
    bound).  The scan version silently exceeded the chain bound, making keys
    unfindable; the vectorized engine refuses instead so callers can grow.
  * ``grow(hm)`` rebuilds into a larger arena (``growth_factor`` x buckets
    and overflow pages), re-bucketing every live entry, rebuilding chains and
    (for the bitserial backend) the bit-planes from scratch.  ``compact(hm)``
    is the same rebuild at the current size: it reclaims all tombstoned slots
    and overflow pages (the paper's "wasted space", §2.5).  Both preserve
    relative chain order of same-key duplicates, so probe/delete semantics
    are unchanged across resizes.  Both are jit-compatible for a fixed
    (old config, new config) pair — shapes are static per config.
  * ``insert_auto`` is the HOST-level policy loop (not jit-compatible:
    growth changes array shapes): it grows proactively when the batch would
    push the load factor past ``config.max_load_factor`` and reactively while
    any element reports ok=False, up to ``max_grows`` doublings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import HashMemConfig
from repro.core import layout
from repro.core.hashing import (EMPTY_KEY, TOMBSTONE_KEY, bits_used,
                                fingerprint, hash_to_bucket, hash_to_bucket2)

I32 = jnp.int32
U32 = jnp.uint32

# bucket_fn(keys (N,) u32, cfg) -> (N,) i32 bucket ids (see grow/_rebuild);
# None means the default hash_to_bucket(cfg) assignment.
BucketFn = Callable[[jax.Array, HashMemConfig], jax.Array]


@partial(jax.tree_util.register_dataclass,
         data_fields=["store", "bucket_head"],
         meta_fields=["config"])
@dataclass
class HashMem:
    store: layout.PageStore       # interleaved pool + page bookkeeping
    bucket_head: jax.Array        # (num_buckets,) int32
    config: HashMemConfig

    # -- thin split views (external callers / differential harness) --------
    @property
    def key_pages(self) -> jax.Array:      # (num_pages, slots) uint32
        return self.store.key_pages

    @property
    def val_pages(self) -> jax.Array:      # (num_pages, slots) uint32
        return self.store.val_pages

    @property
    def planes(self) -> Optional[jax.Array]:
        return self.store.planes

    @property
    def page_next(self) -> jax.Array:      # (num_pages,) int32, -1 terminal
        return self.store.page_next

    @property
    def page_fill(self) -> jax.Array:      # (num_pages,) int32 high-water
        return self.store.page_fill

    @property
    def free_top(self) -> jax.Array:       # () int32 pim_malloc bump pointer
        return self.store.free_top


def _keep_planes(cfg: HashMemConfig) -> bool:
    return cfg.backend == "bitserial"


def _check_resize(cfg: HashMemConfig) -> Optional[int]:
    """Validate the resize knob; returns the global depth for extendible
    tables (None for rebuild).  Extendible resize needs a power-of-two
    directory (the bucket id IS the low-bits hash prefix) and excludes the
    displacement/stash paths (a displaced entry lives at H1 OR H2, so a
    single group's entries are not re-bucketable in isolation)."""
    if cfg.resize not in ("rebuild", "extendible"):
        raise ValueError(f"unknown resize mode {cfg.resize!r} "
                         f"(want 'rebuild' or 'extendible')")
    if cfg.resize != "extendible":
        return None
    if cfg.displacement or cfg.stash_slots:
        raise ValueError("resize='extendible' excludes displacement/stash "
                         "(split re-buckets one group in isolation; a "
                         "displaced entry's home is H1 OR H2)")
    return bits_used(cfg.num_buckets)


def create(cfg: HashMemConfig) -> HashMem:
    """Empty HashMem: every bucket pre-owns its direct page (paper §2.4)."""
    gd = _check_resize(cfg)
    store = layout.empty_store(cfg.num_pages, cfg.slots_per_page,
                               cfg.key_bits, with_planes=_keep_planes(cfg),
                               fp_bits=cfg.fingerprint_bits,
                               stash_slots=cfg.stash_slots,
                               local_depth=gd)
    store = dataclasses.replace(
        store, free_top=jnp.asarray(cfg.num_buckets, dtype=I32))
    return HashMem(
        store=store,
        bucket_head=jnp.arange(cfg.num_buckets, dtype=I32),
        config=cfg,
    )


# ---------------------------------------------------------------------------
# Bulk build (vectorized; the paper populates the dataset before probing)
# ---------------------------------------------------------------------------

def build(cfg: HashMemConfig, keys: jax.Array, vals: jax.Array) -> HashMem:
    """Vectorized bulk load of N key/value pairs.

    Buckets receive ceil(count/slots) pages; overflow pages are allocated
    contiguously from the arena in bucket order.  Duplicate keys are all
    stored; probe returns the first match in chain order.
    """
    b = hash_to_bucket(keys.astype(U32), cfg.num_buckets, cfg.hash_fn, cfg.salt)
    return build_with_buckets(cfg, keys, vals, b)


def build_with_buckets(cfg: HashMemConfig, keys: jax.Array, vals: jax.Array,
                       b: jax.Array) -> HashMem:
    """Bulk load with caller-supplied bucket ids (used by the RLU channel
    layer, which derives (owner shard, local bucket) from one global hash).

    Under ``cfg.displacement`` the load is replayed through the displaced
    insert path (EMPTY_KEY pads are dropped, not stored, unlike the
    chained bulk loader which stores whatever it is given)."""
    if cfg.displacement:
        k = keys.astype(U32)
        hm, _ = _insert_displaced(create(cfg), k, vals, b,
                                  valid=k != EMPTY_KEY)
        return hm
    return _scatter_build(cfg, keys, vals, b, valid=None)


def _scatter_build(cfg: HashMemConfig, keys: jax.Array, vals: jax.Array,
                   b: jax.Array, valid: Optional[jax.Array]) -> HashMem:
    """Shared sort/rank/segment bulk loader.  Entries with ``valid=False``
    (or bucket id >= num_buckets) are dropped; relative order of surviving
    entries within a bucket follows their input order (stable sort)."""
    cfg_slots = cfg.slots_per_page
    n = keys.shape[0]
    keys = keys.astype(U32)
    vals = vals.astype(U32)
    b = b.astype(I32)
    if valid is not None:
        b = jnp.where(valid, b, cfg.num_buckets)               # sorts to the end
    order = jnp.argsort(b)
    bs, ks, vs = b[order], keys[order], vals[order]
    dropped = bs >= cfg.num_buckets

    start = jnp.searchsorted(bs, bs, side="left")
    rank = jnp.arange(n, dtype=I32) - start.astype(I32)                    # rank in bucket
    depth = rank // cfg_slots
    slot = rank % cfg_slots

    counts = jnp.zeros((cfg.num_buckets,), I32).at[bs].add(1, mode="drop")
    n_over = jnp.maximum((counts + cfg_slots - 1) // cfg_slots - 1, 0)     # overflow pages/bucket
    over_off = jnp.cumsum(n_over) - n_over                                 # exclusive prefix

    ob = jnp.minimum(bs, cfg.num_buckets - 1)                              # safe gather
    page = jnp.where(depth == 0, bs,
                     cfg.num_buckets + over_off[ob] + depth - 1)
    page = jnp.where(dropped, cfg.num_pages, page).astype(I32)             # OOB -> dropped

    pool = layout.empty_pool(cfg.num_pages, cfg_slots)
    pool = pool.at[page, slot].set(jnp.stack([ks, vs], axis=-1), mode="drop")
    page_fill = jnp.zeros((cfg.num_pages,), I32).at[page].max(slot + 1,
                                                              mode="drop")

    # chain links: first element landing on a depth>=1 page links prev -> page
    is_link = (depth >= 1) & (slot == 0) & ~dropped
    prev_page = jnp.where(depth == 1, bs,
                          cfg.num_buckets + over_off[ob] + depth - 2).astype(I32)
    link_idx = jnp.where(is_link, prev_page, cfg.num_pages)                # OOB -> dropped
    page_next = jnp.full((cfg.num_pages,), -1, I32).at[link_idx].set(page, mode="drop")

    free_top = cfg.num_buckets + jnp.sum(n_over)
    planes = layout.pack_bitplanes(pool[..., layout.KEY_LANE], cfg.key_bits) \
        if _keep_planes(cfg) else None
    fprints = None
    if cfg.fingerprint_bits > 0:
        fprints = layout.pack_bitplanes(
            fingerprint(pool[..., layout.KEY_LANE], cfg.fingerprint_bits),
            cfg.fingerprint_bits)
    stash = stash_fill = None
    if cfg.stash_slots > 0:
        stash = jnp.broadcast_to(jnp.array([EMPTY_KEY, 0], dtype=U32),
                                 (cfg.stash_slots, 2))
        stash_fill = jnp.asarray(0, dtype=I32)
    # extendible tables leave a (re)build with a flat directory: every group
    # back at the global depth, all leaked split pages reclaimed
    gd = _check_resize(cfg)
    depths = None if gd is None else jnp.full((cfg.num_pages,), gd, I32)

    store = layout.PageStore(pool=pool, planes=planes, page_next=page_next,
                             page_fill=page_fill,
                             free_top=free_top.astype(I32),
                             key_bits=cfg.key_bits,
                             fprints=fprints, stash=stash,
                             stash_fill=stash_fill,
                             local_depth=depths,
                             fp_bits=cfg.fingerprint_bits)
    return HashMem(store=store,
                   bucket_head=jnp.arange(cfg.num_buckets, dtype=I32),
                   config=cfg)


def _fit_report(counts, cfg: HashMemConfig) -> dict:
    """Shared fit check: would per-bucket `counts` fit the chain/arena bounds?"""
    import numpy as np
    pages = np.maximum((counts + cfg.slots_per_page - 1) // cfg.slots_per_page, 0)
    return {
        "max_chain_needed": int(pages.max(initial=0)),
        "overflow_pages_needed": int(np.maximum(pages - 1, 0).sum()),
        "fits": bool(pages.max(initial=0) <= cfg.max_chain
                     and np.maximum(pages - 1, 0).sum() <= cfg.overflow_pages),
    }


def build_check(cfg: HashMemConfig, keys) -> dict:
    """Pre-flight (non-jit) checks that the arena/chain bounds suffice."""
    import numpy as np
    b = np.asarray(hash_to_bucket(jnp.asarray(keys, U32), cfg.num_buckets,
                                  cfg.hash_fn, cfg.salt))
    counts = np.bincount(b, minlength=cfg.num_buckets)
    rep = _fit_report(counts, cfg)
    rep["load_factor"] = float(counts.sum() / (cfg.num_pages * cfg.slots_per_page))
    rep["bucket_counts"] = counts
    return rep


# ---------------------------------------------------------------------------
# RLU command-stream resolution (paper §2.3: RLU locates subarray rows)
# ---------------------------------------------------------------------------

def resolve_pages(hm: HashMem, queries: jax.Array) -> jax.Array:
    """queries (Q,) uint32 -> (Q, max_chain) int32 page ids, -1 padded.

    This is the RLU step: translate each probe key into the ordered list of
    subarray rows (pages) to activate.  Bounded by config.max_chain.
    """
    cfg = hm.config
    b = hash_to_bucket(queries.astype(U32), cfg.num_buckets, cfg.hash_fn, cfg.salt)
    return resolve_pages_by_bucket(hm, b)


def resolve_pages_by_bucket(hm: HashMem, b: jax.Array) -> jax.Array:
    cfg = hm.config
    page = hm.bucket_head[b]                                              # (Q,)
    cols = [page]
    for _ in range(cfg.max_chain - 1):
        nxt = jnp.where(page >= 0, hm.page_next[jnp.maximum(page, 0)], -1)
        cols.append(nxt)
        page = nxt
    return jnp.stack(cols, axis=1).astype(I32)


def chain_lengths(hm: HashMem) -> jax.Array:
    """(num_buckets,) int32 chain lengths via a bounded vectorized walk.

    Walks one step past ``config.max_chain`` so an over-long chain (an
    invariant violation) is visible as a length of max_chain + 1.
    """
    cfg = hm.config
    p = hm.bucket_head
    clen = (p >= 0).astype(I32)
    for _ in range(cfg.max_chain):
        p = jnp.where(p >= 0, hm.page_next[jnp.maximum(p, 0)], -1)
        clen = clen + (p >= 0).astype(I32)
    return clen


def max_chain_len(hm: HashMem) -> int:
    """Longest bucket chain, in pages (the per-probe RLU command depth)."""
    return int(jnp.max(chain_lengths(hm)))


def compact_due(hm: HashMem, tombstones: int, *, fraction: bool = True,
                chain: bool = True) -> bool:
    """THE compaction trigger policy (single definition for every serving
    layer — PageTableManager and ServingEngine): with tombstones present,
    compact when they exceed ``compact_tombstone_frac`` of capacity
    (``fraction``) or, with ``compact_chain_len`` > 0, when any bucket
    chain exceeds that many pages (``chain`` — a device walk + host sync;
    callers that need to throttle it pass chain=False on cheap checks)."""
    cfg = hm.config
    if tombstones <= 0:
        return False
    if fraction and \
            tombstones > cfg.compact_tombstone_frac * cfg.num_pages * \
            cfg.slots_per_page:
        return True
    return chain and cfg.compact_chain_len > 0 and \
        max_chain_len(hm) > cfg.compact_chain_len


# ---------------------------------------------------------------------------
# Probe / insert / delete
# ---------------------------------------------------------------------------

def resolve_pages_displaced(hm: HashMem, queries: jax.Array,
                            b1: Optional[jax.Array] = None) -> jax.Array:
    """Displaced page schedule: [H1 direct page] + [H2 chain], -1 padded.

    Search order matches the displaced insert's placement order (H1 direct
    first, then the H2 chain, then the stash — handled by the caller), so
    the first match is still the oldest duplicate.  When b1 == b2 the H2
    chain's head duplicates the direct page; it is blanked to -1 (only
    position 0 can collide: overflow pages sit above num_buckets)."""
    cfg = hm.config
    q = queries.astype(U32)
    if b1 is None:
        b1 = hash_to_bucket(q, cfg.num_buckets, cfg.hash_fn, cfg.salt)
    b2 = hash_to_bucket2(q, cfg.num_buckets, cfg.hash_fn, cfg.salt)
    direct = hm.bucket_head[b1.astype(I32)][:, None]                  # (Q, 1)
    chain = resolve_pages_by_bucket(hm, b2)                           # (Q, C)
    head = jnp.where(chain[:, :1] == direct, -1, chain[:, :1])
    return jnp.concatenate([direct, head, chain[:, 1:]], axis=1).astype(I32)


def _fp_filter(store: layout.PageStore, queries: jax.Array,
               pages: jax.Array) -> jax.Array:
    """Fingerprint pre-pass: blank (to -1) every page of the schedule whose
    fingerprint lane holds no slot matching the query's fingerprint.

    This is the Dash trick on the paper's bit-plane layout: fp_bits narrow
    plane words are scanned INSTEAD of activating the full (slots, 2) row;
    only fp-matching rows survive to the wide fetch.  True matches are never
    filtered (the lane is exact per slot); false positives (~S/2^fp_bits
    slots per page) cost one extra row activation and are rejected by the
    full key compare."""
    fb = store.fp_bits
    qfp = fingerprint(queries.astype(U32), fb)                        # (Q,)
    rows = store.fprints[jnp.maximum(pages, 0)]                       # (Q,C,fb,W)
    j = jnp.arange(fb, dtype=U32)
    qbits = (qfp[:, None] >> j[None, :]) & U32(1)                     # (Q, fb)
    qwords = jnp.where(qbits == U32(1), U32(0xFFFFFFFF), U32(0))
    mism = rows ^ qwords[:, None, :, None]                            # (Q,C,fb,W)
    agg = mism[:, :, 0, :]
    for i in range(1, fb):       # OR over planes: bit set => some bit differs
        agg = agg | mism[:, :, i, :]
    hit = jnp.any(~agg != U32(0), axis=-1)                            # (Q, C)
    return jnp.where(hit & (pages >= 0), pages, -1)


def stash_probe(store: layout.PageStore, queries: jax.Array):
    """(values, found) against the stash only — whole-stash compare, zero
    row activations (the stash is register-resident by design)."""
    q = queries.astype(U32)
    m = store.stash[None, :, 0] == q[:, None]                         # (Q, T)
    sf = jnp.any(m, axis=1)
    sv = store.stash[jnp.argmax(m, axis=1), 1]    # argmax = oldest match
    return jnp.where(sf, sv, U32(0)), sf


def probe(hm: HashMem, queries: jax.Array, backend: Optional[str] = None):
    """Batched probe.  Returns (values (Q,) uint32, found (Q,) bool)."""
    cfg = hm.config
    b = hash_to_bucket(queries.astype(U32), cfg.num_buckets, cfg.hash_fn,
                       cfg.salt)
    return probe_with_buckets(hm, queries, b, backend)


def probe_with_buckets(hm: HashMem, queries: jax.Array, b: jax.Array,
                       backend: Optional[str] = None):
    """``probe`` with caller-supplied H1 bucket ids (RLU channel layer).

    Pipeline: resolve the page schedule (displaced or chained), fingerprint-
    filter it when the lane is present, hand the surviving pages to the
    backend, then fold in the stash (pool matches win: stash entries are by
    construction the NEWEST duplicates of their key)."""
    from repro.core.probe import probe_pages   # local import to avoid cycle
    cfg = hm.config
    q = queries.astype(U32)
    if cfg.displacement:
        pages = resolve_pages_displaced(hm, q, b)
    else:
        pages = resolve_pages_by_bucket(hm, b.astype(I32))
    if hm.store.fprints is not None:
        pages = _fp_filter(hm.store, q, pages)
    vals, found = probe_pages(hm, q, pages, backend=backend or cfg.backend)
    if hm.store.stash is not None:
        sv, sf = stash_probe(hm.store, q)
        vals = jnp.where(found, vals, sv)
        found = found | sf
    return vals, found


def rows_activated_per_probe(hm: HashMem, queries: jax.Array,
                             use_fingerprints: bool = True,
                             b: Optional[jax.Array] = None) -> jax.Array:
    """Traced mean DRAM-row activations one probe of this batch costs —
    the paper's unit of probe work, derived the same way kernel_bench's
    ``scatters_per_insert`` is (from the op structure, not a timer).

    A hit activates every unfiltered page up to and including the first
    true match; a miss activates every unfiltered page of its schedule.
    The stash is register-resident and counts zero."""
    cfg = hm.config
    q = queries.astype(U32)
    if b is None:
        b = hash_to_bucket(q, cfg.num_buckets, cfg.hash_fn, cfg.salt)
    if cfg.displacement:
        pages = resolve_pages_displaced(hm, q, b)
    else:
        pages = resolve_pages_by_bucket(hm, b.astype(I32))
    if use_fingerprints and hm.store.fprints is not None:
        pages = _fp_filter(hm.store, q, pages)
    valid = pages >= 0
    rows = hm.key_pages[jnp.maximum(pages, 0)]                        # (Q,C,S)
    pmatch = jnp.any(rows == q[:, None, None], axis=-1) & valid
    anym = jnp.any(pmatch, axis=1)
    first = jnp.argmax(pmatch, axis=1)
    upto = jnp.arange(pages.shape[1], dtype=I32)[None, :] <= first[:, None]
    acts = jnp.where(anym, jnp.sum((valid & upto).astype(I32), axis=1),
                     jnp.sum(valid.astype(I32), axis=1))
    return jnp.mean(acts.astype(jnp.float32))


def _write_key_bits(planes, page, slot, key, key_bits: int):
    """Incremental bit-plane maintenance for a single (page, slot) write."""
    word = slot // 32
    bit = (slot % 32).astype(U32)
    j = jnp.arange(key_bits, dtype=U32)
    kbits = ((key.astype(U32) >> j) & U32(1))                              # (b,)
    old = planes[page, :, word]                                           # (b,)
    mask = ~(U32(1) << bit)
    new = (old & mask) | (kbits << bit)
    return planes.at[page, :, word].set(new)


def _chain_tails(hm: HashMem, b: jax.Array):
    """Per-key chain tail page, tail fill and chain length (bounded walk)."""
    cfg = hm.config
    tail = hm.bucket_head[b]                                              # (B,)
    clen = jnp.ones_like(tail)
    for _ in range(cfg.max_chain - 1):
        nxt = hm.page_next[tail]
        has = nxt >= 0
        tail = jnp.where(has, nxt, tail)
        clen = clen + has.astype(I32)
    return tail, hm.page_fill[tail], clen


def insert(hm: HashMem, keys: jax.Array, vals: jax.Array,
           valid: Optional[jax.Array] = None):
    """Vectorized batched insert: appends the whole batch at the existing
    chain tails in one shot (sort/rank/segment, same machinery as
    ``build_with_buckets``).  Equivalent to repeated single inserts in batch
    order.  Returns (new_hm, ok (B,) bool); see the module docstring for the
    ok=False (PR_ERROR) semantics.

    ``valid`` (optional (B,) bool) marks padding: invalid elements write
    nothing, claim no arena pages and report ok=False — the serving engine
    pads insert batches to power-of-two shapes to bound the set of compiled
    shapes (engine.py).
    """
    cfg = hm.config
    b = hash_to_bucket(keys.astype(U32), cfg.num_buckets, cfg.hash_fn, cfg.salt)
    return insert_with_buckets(hm, keys, vals, b, valid)


def insert_with_buckets(hm: HashMem, keys: jax.Array, vals: jax.Array,
                        b: jax.Array, valid: Optional[jax.Array] = None):
    """``insert`` with caller-supplied bucket ids (RLU channel layer).

    Dispatches to the displaced path (H1 direct -> H2 chain -> stash) when
    ``config.displacement`` is set, else to the chained append."""
    if hm.config.displacement:
        return _insert_displaced(hm, keys, vals, b, valid)
    return _insert_chained(hm, keys, vals, b, valid)


def _insert_chained(hm: HashMem, keys: jax.Array, vals: jax.Array,
                    b: jax.Array, valid: Optional[jax.Array] = None):
    """Chain-append insert at the buckets' existing tails.

    Three pool-shaped scatters total: the fused key/value row write
    (store.write_slots), the fill high-water max, and the chain-link set;
    the per-element ok mask is un-permuted with a gather, not a scatter.
    """
    cfg = hm.config
    slots = cfg.slots_per_page
    n = keys.shape[0]
    keys = keys.astype(U32)
    vals = vals.astype(U32)
    b = b.astype(I32)
    if valid is not None:
        b = jnp.where(valid, b, cfg.num_buckets)   # pads sort to the end
    if cfg.resize == "extendible" and hm.store.local_depth is not None:
        # canonicalize to the group id (low local_depth bits): directory
        # aliases of one group must form ONE sort segment below, or two
        # aliased buckets would both append at the same tail fill and
        # collide on slots.  Probe/delete need no such fold — the aliased
        # bucket_head gather already lands on the shared chain.
        heads = hm.bucket_head[jnp.minimum(b, cfg.num_buckets - 1)]
        ld = hm.store.local_depth[heads]
        mask = (jnp.int32(1) << ld) - 1
        b = jnp.where(b < cfg.num_buckets, b & mask, b)

    # clamped gather: dropped entries read bucket 0's tail, never used
    tail, fill, clen = _chain_tails(hm, jnp.minimum(b, cfg.num_buckets - 1))

    # stable sort by bucket keeps intra-bucket batch order (duplicate keys
    # land in insertion order, matching sequential semantics)
    order = jnp.argsort(b)
    bs, ks, vs = b[order], keys[order], vals[order]
    tails, fills, clens = tail[order], fill[order], clen[order]
    dropped = bs >= cfg.num_buckets

    start = jnp.searchsorted(bs, bs, side="left")
    rank = jnp.arange(n, dtype=I32) - start.astype(I32)
    pos = fills + rank                          # position past the tail start
    depth = pos // slots                        # 0 = existing tail page
    slot = pos % slots

    # pim_malloc: every chain-admissible page start claims the next arena
    # page, in sorted (bucket) order — one cumsum, no per-bucket arrays.
    # Pages of one bucket stay contiguous (no other bucket's start can fall
    # between two starts of the same bucket segment).
    ok_chain = (clens + depth <= cfg.max_chain) & ~dropped  # RLU depth bound
    is_new_page = ok_chain & (depth >= 1) & (slot == 0)
    page_idx = jnp.cumsum(is_new_page.astype(I32)) - 1     # shared along page
    new_id = hm.free_top + page_idx
    n_fit = jnp.clip(cfg.num_pages - hm.free_top, 0,
                     jnp.sum(is_new_page.astype(I32)))
    ok = jnp.where(depth == 0, ~dropped, ok_chain & (new_id < cfg.num_pages))
    page = jnp.where(depth == 0, tails, new_id).astype(I32)
    wp = jnp.where(ok, page, cfg.num_pages)                # OOB drop if !ok

    store = hm.store.write_slots(wp, slot, ks, vs)         # fused k+v scatter
    page_fill = store.page_fill.at[wp].max(slot + 1, mode="drop")

    # chain links: first element on each newly allocated page links prev -> page
    is_link = ok & (depth >= 1) & (slot == 0)
    prev = jnp.where(depth == 1, tails, page - 1)
    link_idx = jnp.where(is_link, prev, cfg.num_pages)
    page_next = store.page_next.at[link_idx].set(page, mode="drop")

    store = dataclasses.replace(
        store, page_fill=page_fill, page_next=page_next,
        free_top=(hm.free_top + n_fit).astype(I32))

    ok_orig = ok[jnp.argsort(order)]            # inverse permutation (gather)
    return HashMem(store=store, bucket_head=hm.bucket_head,
                   config=cfg), ok_orig


def _insert_displaced(hm: HashMem, keys: jax.Array, vals: jax.Array,
                      b1: jax.Array, valid: Optional[jax.Array] = None):
    """IcebergHT-style displaced insert: three rounds.

      1. H1 direct page only (no chaining): fill-ranked append into the
         bucket's own row while it has room.
      2. Residue chains at H2 (``hash_to_bucket2``) via the normal chained
         append — this is the only round that allocates overflow pages, so
         chains grow at the SECOND hash's (near-uniform) bucket, not at the
         skewed H1 hot spot.
      3. Whatever both buckets reject falls into the stash (bump-allocated;
         slots are not reused until a rebuild).

    A key's round class is non-decreasing over its duplicates' lifetimes
    (direct fill and chain capacity are monotone), and probes search
    direct -> H2 chain -> stash, so the first match remains the OLDEST
    duplicate — the same FIFO contract as the chained path.
    """
    cfg = hm.config
    S = cfg.slots_per_page
    n = keys.shape[0]
    keys = keys.astype(U32)
    vals = vals.astype(U32)
    b1 = b1.astype(I32)
    valid_all = jnp.ones((n,), bool) if valid is None else valid

    # -- round 1: H1 direct page, fill-only (never allocates, never links) --
    b = jnp.where(valid_all, b1, cfg.num_buckets)          # pads sort to end
    order = jnp.argsort(b)
    bs, ks, vs = b[order], keys[order], vals[order]
    dropped = bs >= cfg.num_buckets
    head = hm.bucket_head[jnp.minimum(bs, cfg.num_buckets - 1)]
    fill = hm.page_fill[head]
    start = jnp.searchsorted(bs, bs, side="left")
    rank = jnp.arange(n, dtype=I32) - start.astype(I32)
    pos = fill + rank
    ok1s = (pos < S) & ~dropped
    wp = jnp.where(ok1s, head, cfg.num_pages)              # OOB drop if !ok
    slot = jnp.minimum(pos, S - 1).astype(I32)
    store = hm.store.write_slots(wp, slot, ks, vs)
    page_fill = store.page_fill.at[wp].max(slot + 1, mode="drop")
    store = dataclasses.replace(store, page_fill=page_fill)
    hm1 = HashMem(store=store, bucket_head=hm.bucket_head, config=cfg)
    ok1 = ok1s[jnp.argsort(order)]

    # -- round 2: chain the residue at H2 ----------------------------------
    b2 = hash_to_bucket2(keys, cfg.num_buckets, cfg.hash_fn, cfg.salt)
    hm2, ok2 = _insert_chained(hm1, keys, vals, b2, valid_all & ~ok1)

    # -- round 3: stash the rest (batch order == age order) ----------------
    st = hm2.store
    if st.stash is None:
        return hm2, ok1 | ok2
    T = st.stash.shape[0]
    valid3 = valid_all & ~ok1 & ~ok2
    rank3 = jnp.cumsum(valid3.astype(I32)) - valid3.astype(I32)
    pos3 = st.stash_fill + rank3
    ok3 = valid3 & (pos3 < T)
    sp = jnp.where(ok3, pos3, T)                           # OOB drop if !ok
    stash = st.stash.at[sp].set(jnp.stack([keys, vals], axis=-1),
                                mode="drop")
    stash_fill = (st.stash_fill + jnp.sum(ok3.astype(I32))).astype(I32)
    store = dataclasses.replace(st, stash=stash, stash_fill=stash_fill)
    return HashMem(store=store, bucket_head=hm2.bucket_head,
                   config=cfg), ok1 | ok2 | ok3


def insert_scan(hm: HashMem, keys: jax.Array, vals: jax.Array):
    """Sequential per-element insert (paper §3.1 Listing 1) via ``lax.scan``.

    Kept as the reference semantics for the vectorized ``insert`` (see the
    differential tests) and as the benchmark baseline.  NOTE: unlike
    ``insert``, this version does not enforce the max_chain bound.
    """
    cfg = hm.config
    slots = cfg.slots_per_page

    def step(state, kv):
        pool, planes, fprints, page_next, page_fill, free_top = state
        k, v = kv
        b = hash_to_bucket(k[None], cfg.num_buckets, cfg.hash_fn, cfg.salt)[0]
        # walk to chain tail (bounded)
        last = hm.bucket_head[b]
        for _ in range(cfg.max_chain - 1):
            nxt = page_next[jnp.maximum(last, 0)]
            last = jnp.where(nxt >= 0, nxt, last)
        fill = page_fill[last]
        need_new = fill >= slots
        new_page = free_top
        ok = jnp.where(need_new, new_page < cfg.num_pages, True)
        tp = jnp.where(need_new, new_page, last).astype(I32)
        ts = jnp.where(need_new, 0, fill).astype(I32)
        wp = jnp.where(ok, tp, cfg.num_pages)                              # OOB drop if !ok
        pool = pool.at[wp, ts].set(jnp.stack([k, v]), mode="drop")  # fused k+v
        if planes is not None:
            planes = jnp.where(ok, _write_key_bits(planes, tp, ts, k, cfg.key_bits), planes)
        if fprints is not None:
            fprints = jnp.where(
                ok, _write_key_bits(fprints, tp, ts,
                                    fingerprint(k, cfg.fingerprint_bits),
                                    cfg.fingerprint_bits), fprints)
        page_fill = page_fill.at[wp].set(ts + 1, mode="drop")
        do_link = need_new & ok
        page_next = page_next.at[jnp.where(do_link, last, cfg.num_pages)].set(
            new_page, mode="drop")
        free_top = free_top + do_link.astype(I32)
        return (pool, planes, fprints, page_next, page_fill, free_top), ok

    init = (hm.store.pool, hm.planes, hm.store.fprints, hm.page_next,
            hm.page_fill, hm.free_top)
    (pool, pl, fp, pn, pf, ft), oks = jax.lax.scan(
        step, init, (keys.astype(U32), vals.astype(U32)))
    store = dataclasses.replace(hm.store, pool=pool, planes=pl, fprints=fp,
                                page_next=pn, page_fill=pf, free_top=ft)
    return HashMem(store=store, bucket_head=hm.bucket_head, config=cfg), oks


def delete(hm: HashMem, keys: jax.Array):
    """Batched tombstone delete (paper §2.5).  Returns (new_hm, found).
    Each query tombstones the FIRST chain-order match of its key; duplicate
    queries in one batch resolve to the same slot (one removal).  Only the
    key lane of the row is rewritten — the value is the paper's "wasted
    space" until compact()."""
    cfg = hm.config
    b = hash_to_bucket(keys.astype(U32), cfg.num_buckets, cfg.hash_fn,
                       cfg.salt)
    return delete_with_buckets(hm, keys, b)


def delete_with_buckets(hm: HashMem, keys: jax.Array, b: jax.Array):
    """``delete`` with caller-supplied bucket ids (the RLU channel layer
    derives the local bucket from one global hash — see rlu.py)."""
    if hm.config.displacement:
        return _delete_displaced(hm, keys, b)
    cfg = hm.config
    slots = cfg.slots_per_page
    q = keys.astype(U32)
    pages = resolve_pages_by_bucket(hm, b.astype(I32))                     # (Q, C)
    rows = hm.key_pages[jnp.maximum(pages, 0)]                             # (Q, C, S)
    match = (rows == q[:, None, None]) & (pages >= 0)[:, :, None]
    qn, C = pages.shape
    flat = match.reshape(qn, C * slots)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)
    c, s = idx // slots, (idx % slots).astype(I32)
    pg = pages[jnp.arange(qn), c]
    wp = jnp.where(found, pg, cfg.num_pages)                               # OOB drop
    plane_pages = _dedup_plane_pages(hm, found, pg, s)
    store = hm.store.write_keys(wp, s, jnp.full((qn,), TOMBSTONE_KEY, U32),
                                plane_pages=plane_pages)
    return HashMem(store=store, bucket_head=hm.bucket_head,
                   config=cfg), found


def _dedup_plane_pages(hm: HashMem, found, pg, s):
    """Dedup identical (page, slot) tombstone targets (duplicate queries) so
    the batched bit-plane/fingerprint scatters add each bit exactly once;
    None when neither packed lane exists (no dedup needed)."""
    cfg = hm.config
    qn = found.shape[0]
    if (hm.planes is None and hm.store.fprints is None) or qn == 0:
        return None
    flatidx = jnp.where(found, pg * cfg.slots_per_page + s, -1)
    o = jnp.argsort(flatidx)
    fs = flatidx[o]
    first = jnp.concatenate([jnp.ones((1,), bool), fs[1:] != fs[:-1]])
    uniq = jnp.zeros((qn,), bool).at[o].set(first)
    return jnp.where(found & uniq, pg, cfg.num_pages)


def _delete_displaced(hm: HashMem, keys: jax.Array, b1: jax.Array):
    """Tombstone delete over the displaced search order: H1 direct page,
    H2 chain, then the stash.  Stash hits rewrite the stash key lane to
    TOMBSTONE (the slot is reclaimed at the next rebuild, like any
    tombstone); duplicate queries resolve to the same slot."""
    cfg = hm.config
    S = cfg.slots_per_page
    q = keys.astype(U32)
    pages = resolve_pages_displaced(hm, q, b1.astype(I32))                 # (Q, C)
    rows = hm.key_pages[jnp.maximum(pages, 0)]
    match = (rows == q[:, None, None]) & (pages >= 0)[:, :, None]
    qn, C = pages.shape
    flat = match.reshape(qn, C * S)
    st = hm.store
    if st.stash is not None:
        flat = jnp.concatenate([flat, st.stash[None, :, 0] == q[:, None]],
                               axis=1)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)
    in_pool = idx < C * S
    pidx = jnp.minimum(idx, C * S - 1)
    c, s = pidx // S, (pidx % S).astype(I32)
    pg = pages[jnp.arange(qn), c]
    pool_hit = found & in_pool
    wp = jnp.where(pool_hit, pg, cfg.num_pages)                            # OOB drop
    plane_pages = _dedup_plane_pages(hm, pool_hit, pg, s)
    store = st.write_keys(wp, s, jnp.full((qn,), TOMBSTONE_KEY, U32),
                          plane_pages=plane_pages)
    if st.stash is not None:
        sp = jnp.where(found & ~in_pool, idx - C * S, st.stash.shape[0])
        stash = store.stash.at[sp, 0].set(TOMBSTONE_KEY, mode="drop")
        store = dataclasses.replace(store, stash=stash)
    return HashMem(store=store, bucket_head=hm.bucket_head,
                   config=cfg), found


# ---------------------------------------------------------------------------
# Dynamic resizing (grow / compact / auto-grow policy)
# ---------------------------------------------------------------------------

def live_count(hm: HashMem) -> jax.Array:
    """() int32 number of live (non-empty, non-tombstone) entries,
    stash included."""
    kp = hm.key_pages
    n = jnp.sum((kp != EMPTY_KEY) & (kp != TOMBSTONE_KEY)).astype(I32)
    if hm.store.stash is not None:
        sk = hm.store.stash[:, 0]
        n = n + jnp.sum((sk != EMPTY_KEY) & (sk != TOMBSTONE_KEY)).astype(I32)
    return n


def load_factor(hm: HashMem) -> jax.Array:
    """Live entries / total slot capacity, as a traced float32 scalar."""
    cap = hm.config.num_pages * hm.config.slots_per_page
    return live_count(hm).astype(jnp.float32) / jnp.float32(cap)


def _rebuild(hm: HashMem, new_cfg: HashMemConfig,
             bucket_fn: Optional[BucketFn]) -> HashMem:
    """Re-bucket every live entry into a fresh arena under ``new_cfg``.

    Flat (page-major) slot order IS chain order per bucket (page ids increase
    along every chain), so same-key duplicates keep their relative order —
    probe/delete semantics survive the rebuild.  The interleaved pool makes
    this one reshape: rows flatten to (P*S, 2) key/value pairs directly.
    """
    if hm.config.displacement:
        return _rebuild_displaced(hm, new_cfg, bucket_fn)
    flat = hm.store.pool.reshape(-1, 2)
    keys = flat[:, layout.KEY_LANE]
    vals = flat[:, layout.VAL_LANE]
    live = (keys != EMPTY_KEY) & (keys != TOMBSTONE_KEY)
    if bucket_fn is None:
        b = hash_to_bucket(keys, new_cfg.num_buckets, new_cfg.hash_fn,
                           new_cfg.salt)
    else:
        b = bucket_fn(keys, new_cfg)
    return _scatter_build(new_cfg, keys, vals, b, valid=live)


def _rebuild_displaced(hm: HashMem, new_cfg: HashMemConfig,
                       bucket_fn: Optional[BucketFn]) -> HashMem:
    """Displaced rebuild: replay every live entry through the displaced
    insert path, oldest placement class first.

    Flat order alone is NOT age order here (a key's H2 chain entries can sit
    at a lower page id than another key's H1 direct entries), but WITHIN a
    key all duplicates share (b1, b2), so classifying each slot as
    was-H1-direct (its page IS its H1 bucket's own row) vs was-chained and
    replaying class 0, then class 1, then the stash preserves per-key age
    order — the only order probe/delete semantics depend on.  A compact
    never drops entries: the replay faces at least the capacity the entries
    already fit in, and any cascade ends in the (non-decreasing) stash."""
    cfg = hm.config
    S = cfg.slots_per_page
    flat = hm.store.pool.reshape(-1, 2)
    keys = flat[:, layout.KEY_LANE]
    vals = flat[:, layout.VAL_LANE]
    live = (keys != EMPTY_KEY) & (keys != TOMBSTONE_KEY)
    n = keys.shape[0]
    if bucket_fn is None:
        b_old = hash_to_bucket(keys, cfg.num_buckets, cfg.hash_fn, cfg.salt)
    else:
        b_old = bucket_fn(keys, cfg)
    page_of = jnp.arange(n, dtype=I32) // S
    cls = jnp.where(page_of == b_old, 0, 1)
    sortkey = jnp.where(live, cls * n + jnp.arange(n), 2 * n + jnp.arange(n))
    order = jnp.argsort(sortkey)
    ks, vs, lv = keys[order], vals[order], live[order]
    if hm.store.stash is not None:
        sk, sv = hm.store.stash[:, 0], hm.store.stash[:, 1]
        ks = jnp.concatenate([ks, sk])
        vs = jnp.concatenate([vs, sv])
        lv = jnp.concatenate([lv, (sk != EMPTY_KEY) & (sk != TOMBSTONE_KEY)])
    if bucket_fn is None:
        b1 = hash_to_bucket(ks, new_cfg.num_buckets, new_cfg.hash_fn,
                            new_cfg.salt)
    else:
        b1 = bucket_fn(ks, new_cfg)
    hm2, _ = _insert_displaced(create(new_cfg), ks, vs, b1, valid=lv)
    return hm2


def grow(hm: HashMem, factor: Optional[int] = None,
         bucket_fn: Optional[BucketFn] = None) -> HashMem:
    """Rehash into a ``factor``x larger arena (default config.growth_factor):
    num_buckets and overflow_pages both scale, all live entries are
    re-bucketed, chains and bit-planes are rebuilt.  Tombstones are dropped
    (grow subsumes compact)."""
    cfg = hm.config
    f = factor or cfg.growth_factor
    new_cfg = dataclasses.replace(cfg, num_buckets=cfg.num_buckets * f,
                                  overflow_pages=cfg.overflow_pages * f)
    return _rebuild(hm, new_cfg, bucket_fn)


def compact(hm: HashMem, bucket_fn: Optional[BucketFn] = None) -> HashMem:
    """Reclaim tombstoned slots and overflow pages by rebuilding in place
    (same config).  After compact: stats()['tombstones'] == 0 and every
    chain is the minimum length for its live population."""
    return _rebuild(hm, hm.config, bucket_fn)


def rebuild_check(hm: HashMem, new_cfg: HashMemConfig,
                  bucket_fn: Optional[BucketFn] = None) -> dict:
    """Host-side pre-flight: would the live entries fit under new_cfg?"""
    import numpy as np
    keys = np.asarray(hm.key_pages).reshape(-1)
    live = (keys != np.uint32(0xFFFFFFFF)) & (keys != np.uint32(0xFFFFFFFE))
    lk = jnp.asarray(keys[live])
    if bucket_fn is None:
        b = hash_to_bucket(lk, new_cfg.num_buckets, new_cfg.hash_fn,
                           new_cfg.salt)
    else:
        b = bucket_fn(lk, new_cfg)
    counts = np.bincount(np.asarray(b), minlength=new_cfg.num_buckets)
    return _fit_report(counts, new_cfg)


# ---------------------------------------------------------------------------
# Extendible resize (directory-based; Dash) — resize="extendible"
# ---------------------------------------------------------------------------
#
# The existing structure already IS a directory: with num_buckets = 2^gd the
# bucket id (hash % num_buckets) is the low-gd-bits hash prefix, and the
# bucket_head gather every probe/delete/insert performs is the directory
# indirection.  Extendible mode adds per-GROUP local depths (a page lane on
# the store, meaningful at group-head pages): directory entries sharing the
# low local_depth bits alias ONE page-chain group.
#
#   * split_group: an overflowing group (local depth ld < global depth gd)
#     splits ALONE — its live entries are re-bucketed on hash bit ld into
#     the old head and ONE newly allocated page region; the directory
#     aliases are repointed (pointer writes); every other group's pages,
#     chains and directory entries are untouched and probe-able throughout.
#   * double_directory: when ld == gd the directory doubles by POINTER COPY
#     (bucket_head -> concat of itself) with ZERO data movement.  The page
#     arena is deliberately kept the same size (num_buckets doubles,
#     overflow_pages shrinks by the same amount) so every array shape in the
#     store is invariant — only the directory itself reallocates.
#   * grow()/compact() stay available as the fallback/reclaim path: a
#     rebuild under an extendible config resets the directory flat (every
#     group back at depth gd) and reclaims pages leaked by splits (a split
#     abandons its old overflow pages to keep pim_malloc a bump pointer).

def split_group(hm: HashMem, bucket: int,
                bucket_fn: Optional[BucketFn] = None):
    """Split the group owning ``bucket`` one level deeper (HOST-level,
    shape-preserving).  Returns (hm, status):

      * "ok"          — split done; group entries re-bucketed on bit ld.
      * "need_double" — local depth == global depth: double_directory first.
      * "full"        — the arena cannot supply the new head/overflow pages.
      * "stuck"       — a child would exceed max_chain (entries share hash
                        bits past this depth); only a full grow() helps.

    The mutation is ordered like any insert-phase write: it touches only
    this group's pages plus the directory aliases of this group, so every
    concurrent probe of OTHER groups resolves identically before/after."""
    import numpy as np
    cfg = hm.config
    gd = bits_used(cfg.num_buckets)
    S = cfg.slots_per_page
    head0 = int(hm.bucket_head[int(bucket) % cfg.num_buckets])
    ld = int(hm.store.local_depth[head0])
    if ld >= gd:
        return hm, "need_double"
    c = int(bucket) & ((1 << ld) - 1)              # canonical group id

    # walk the chain on the host (bounded) and pull the live entries in
    # chain order — flat page-major slot order IS per-key age order
    pages = []
    page_next = np.asarray(hm.page_next)
    p = head0
    while p >= 0 and len(pages) <= cfg.max_chain:
        pages.append(p)
        p = int(page_next[p])
    flat = np.asarray(hm.store.pool[jnp.asarray(pages, I32)]).reshape(-1, 2)
    k, v = flat[:, 0], flat[:, 1]
    live = (k != np.uint32(0xFFFFFFFF)) & (k != np.uint32(0xFFFFFFFE))
    lk, lv = k[live], v[live]

    # pre-flight: both children must fit their chain/arena bounds BEFORE any
    # mutation (a half-performed split would lose entries)
    if lk.size:
        if bucket_fn is None:
            hb = np.asarray(hash_to_bucket(jnp.asarray(lk), cfg.num_buckets,
                                           cfg.hash_fn, cfg.salt))
        else:
            hb = np.asarray(bucket_fn(jnp.asarray(lk), cfg))
        goes_hi = ((hb >> ld) & 1) == 1
        n_lo, n_hi = int((~goes_hi).sum()), int(goes_hi.sum())
    else:
        n_lo = n_hi = 0
    pg_lo = max(-(-n_lo // S), 1)
    pg_hi = max(-(-n_hi // S), 1)
    if pg_lo > cfg.max_chain or pg_hi > cfg.max_chain:
        return hm, "stuck"
    need = 1 + (pg_lo - 1) + (pg_hi - 1)           # new head + overflow
    free_top = int(hm.free_top)
    if free_top + need > cfg.num_pages:
        return hm, "full"

    # clear the old chain through write_slots (keeps bit-planes and the
    # fingerprint lane consistent), reset its fills/links; overflow pages of
    # the old chain are LEAKED (bump allocator) until compact()/grow()
    new_head = free_top
    L = len(pages)
    store = hm.store.write_slots(
        jnp.asarray(np.repeat(pages, S), I32),
        jnp.asarray(np.tile(np.arange(S), L), I32),
        jnp.full((L * S,), EMPTY_KEY, U32), jnp.zeros((L * S,), U32))
    pg_arr = jnp.asarray(pages, I32)
    both = jnp.asarray([head0, new_head], I32)
    store = dataclasses.replace(
        store,
        page_fill=store.page_fill.at[pg_arr].set(0),
        page_next=store.page_next.at[pg_arr].set(-1),
        local_depth=store.local_depth.at[both].set(ld + 1),
        free_top=jnp.asarray(new_head + 1, I32))

    # directory: the group's aliases are c + m*2^ld; bit ld of the alias
    # (odd m) selects the new head — pointer writes only
    m = jnp.arange(cfg.num_buckets >> ld, dtype=I32)
    idxs = c + (m << ld)
    heads = jnp.where((m & 1) == 1, new_head, head0).astype(I32)
    hm2 = HashMem(store=store,
                  bucket_head=hm.bucket_head.at[idxs].set(heads),
                  config=cfg)

    # re-insert the extracted entries: the insert path's canonicalization
    # routes each to its (depth ld+1) child, preserving chain order
    if lk.size:
        if bucket_fn is None:
            b = hash_to_bucket(jnp.asarray(lk), cfg.num_buckets, cfg.hash_fn,
                               cfg.salt)
        else:
            b = bucket_fn(jnp.asarray(lk), cfg)
        hm2, ok = insert_with_buckets(hm2, jnp.asarray(lk), jnp.asarray(lv), b)
        assert bool(np.asarray(ok).all()), "split re-insert overflowed"
    return hm2, "ok"


def double_directory(hm: HashMem) -> Optional[HashMem]:
    """Double the bucket directory by pointer copy — NO data movement.

    num_buckets doubles while overflow_pages shrinks by the old directory
    size, so ``num_pages`` (and with it every store array shape) is
    INVARIANT: the new directory entries are aliases of their low-half
    groups at unchanged local depths.  Returns None when the overflow
    arena cannot cede num_buckets pages of accounting (the caller falls
    back to a genuine grow() rebuild)."""
    cfg = hm.config
    bits_used(cfg.num_buckets)                     # validate pow2
    if cfg.overflow_pages < cfg.num_buckets:
        return None
    cfg2 = dataclasses.replace(
        cfg, num_buckets=cfg.num_buckets * 2,
        overflow_pages=cfg.overflow_pages - cfg.num_buckets)
    return HashMem(store=hm.store,
                   bucket_head=jnp.concatenate([hm.bucket_head,
                                                hm.bucket_head]),
                   config=cfg2)


def grow_extendible(hm: HashMem, bucket: int,
                    bucket_fn: Optional[BucketFn] = None):
    """Make room in the group owning ``bucket``: split it, doubling the
    directory first when its local depth has reached the global depth.
    Falls back to a full grow() rebuild only when the arena or the chain
    bound cannot admit a split.  Returns (hm, how) with how in
    {"split", "double", "rebuild"} — "double" implies a split happened
    after the doubling."""
    hm2, status = split_group(hm, bucket, bucket_fn=bucket_fn)
    if status == "ok":
        return hm2, "split"
    if status == "need_double":
        doubled = double_directory(hm)
        if doubled is not None:
            hm2, status = split_group(doubled, bucket, bucket_fn=bucket_fn)
            if status == "ok":
                return hm2, "double"
            hm = doubled                           # keep the wider directory
    return grow(hm, bucket_fn=bucket_fn), "rebuild"


def insert_extendible(hm: HashMem, keys: jax.Array, vals: jax.Array,
                      bucket_fn: Optional[BucketFn] = None,
                      max_splits: int = 256, max_grows: int = 8,
                      events: Optional[dict] = None):
    """Host-level insert loop for resize="extendible": refused elements
    trigger per-GROUP splits (plus directory doublings) instead of a
    stop-the-world rehash; a full grow() rebuild remains the bounded
    fallback.  Returns (new_hm, ok (B,) bool).  ``events`` (optional dict)
    accumulates "splits"/"doublings"/"rebuilds" counts for telemetry."""
    import numpy as np
    keys = jnp.asarray(keys).astype(U32)
    vals = jnp.asarray(vals).astype(U32)
    n = keys.shape[0]
    ok = np.zeros((n,), bool)
    remaining = np.arange(n)
    splits = grows = 0
    while remaining.size:
        kr, vr = keys[remaining], vals[remaining]
        if bucket_fn is None:
            br = hash_to_bucket(kr, hm.config.num_buckets, hm.config.hash_fn,
                                hm.config.salt)
        else:
            br = bucket_fn(kr, hm.config)
        hm, ok_r = insert_with_buckets(hm, kr, vr, br)
        ok_np = np.asarray(ok_r)
        ok[remaining[ok_np]] = True
        remaining = remaining[~ok_np]
        if remaining.size == 0:
            break
        if splits >= max_splits or grows > max_grows:
            break
        # split every refused group once, then retry the residue; each
        # successful split strictly deepens a group, so the loop terminates
        for b0 in np.unique(np.asarray(br)[~ok_np]):
            if splits >= max_splits or grows > max_grows:
                break
            hm, how = grow_extendible(hm, int(b0), bucket_fn=bucket_fn)
            splits += 1
            if how == "rebuild":
                grows += 1
            if events is not None:
                key = {"split": "splits", "double": "doublings",
                       "rebuild": "rebuilds"}[how]
                events[key] = events.get(key, 0) + 1
                if how == "double":
                    events["splits"] = events.get("splits", 0) + 1
    return hm, jnp.asarray(ok)


def insert_auto(hm: HashMem, keys: jax.Array, vals: jax.Array,
                bucket_fn: Optional[BucketFn] = None, max_grows: int = 8,
                events: Optional[dict] = None):
    """Host-level insert with auto-grow (NOT jit-compatible: growth changes
    array shapes).  Grows proactively when the batch would exceed
    config.max_load_factor and reactively while any element fails — the two
    loops draw on SEPARATE ``max_grows`` budgets (a proactive doubling must
    never starve the reactive repair of an ok=False batch into a spurious
    refusal).  Under resize="extendible" the reactive path splits the
    refused groups (insert_extendible) instead of rebuilding.  Returns
    (new_hm, ok (B,) bool) — ok is all-True unless growth was
    exhausted/disabled."""
    import numpy as np
    keys = jnp.asarray(keys).astype(U32)
    vals = jnp.asarray(vals).astype(U32)
    n = keys.shape[0]
    cfg = hm.config
    if cfg.auto_grow:
        proactive = 0
        cap = cfg.num_pages * cfg.slots_per_page
        live = int(live_count(hm))
        while (live + n) > cfg.max_load_factor * cap \
                and proactive < max_grows:
            hm = grow(hm, bucket_fn=bucket_fn)
            cfg = hm.config
            cap = cfg.num_pages * cfg.slots_per_page
            proactive += 1
            if events is not None:
                events["rebuilds"] = events.get("rebuilds", 0) + 1

    if cfg.resize == "extendible" and cfg.auto_grow:
        return insert_extendible(hm, keys, vals, bucket_fn=bucket_fn,
                                 max_grows=max_grows, events=events)

    ok = np.zeros((n,), bool)
    remaining = np.arange(n)
    reactive = 0
    while remaining.size:
        kr, vr = keys[remaining], vals[remaining]
        if bucket_fn is None:
            br = hash_to_bucket(kr, hm.config.num_buckets, hm.config.hash_fn,
                                hm.config.salt)
        else:
            br = bucket_fn(kr, hm.config)
        hm, ok_r = insert_with_buckets(hm, kr, vr, br)
        ok_np = np.asarray(ok_r)
        ok[remaining[ok_np]] = True
        remaining = remaining[~ok_np]
        if remaining.size == 0:
            break
        if not hm.config.auto_grow or reactive >= max_grows:
            break
        hm = grow(hm, bucket_fn=bucket_fn)
        reactive += 1
        if events is not None:
            events["rebuilds"] = events.get("rebuilds", 0) + 1
    return hm, jnp.asarray(ok)


# ---------------------------------------------------------------------------
# Introspection (fig. 4 reproduction + invariants for property tests)
# ---------------------------------------------------------------------------

def stats(hm: HashMem) -> dict:
    import numpy as np
    cfg = hm.config
    kp = np.asarray(hm.key_pages)
    fill = np.asarray(hm.page_fill)
    live = (kp != np.uint32(0xFFFFFFFF)) & (kp != np.uint32(0xFFFFFFFE))
    chain_len = np.asarray(chain_lengths(hm))
    cap = cfg.num_pages * cfg.slots_per_page
    stash_live = stash_tomb = stash_fill = 0
    if hm.store.stash is not None:
        sk = np.asarray(hm.store.stash[:, 0])
        stash_live = int(((sk != np.uint32(0xFFFFFFFF))
                          & (sk != np.uint32(0xFFFFFFFE))).sum())
        stash_tomb = int((sk == np.uint32(0xFFFFFFFE)).sum())
        stash_fill = int(np.asarray(hm.store.stash_fill))
    return {
        "live_entries": int(live.sum()) + stash_live,
        "tombstones": int((kp == np.uint32(0xFFFFFFFE)).sum()) + stash_tomb,
        "pages_used": int(np.sum(fill > 0)),
        "free_pages": int(cfg.num_pages - np.asarray(hm.free_top)),
        "chain_lengths": chain_len,
        "max_chain": int(chain_len.max(initial=0)),
        "capacity": cap,
        "load_factor": float((live.sum() + stash_live) / cap),
        "num_buckets": cfg.num_buckets,
        "stash_live": stash_live,
        "stash_tombstones": stash_tomb,
        "stash_fill": stash_fill,
    } | ({
        # extendible-resize telemetry: directory size == num_buckets;
        # local depths read at the group-head pages the directory points to
        "global_depth": bits_used(cfg.num_buckets),
        "min_local_depth": int(np.asarray(
            hm.store.local_depth[hm.bucket_head]).min()),
        "max_local_depth": int(np.asarray(
            hm.store.local_depth[hm.bucket_head]).max()),
    } if hm.store.local_depth is not None else {})
