"""Structural jaxpr introspection shared by benchmarks and tests.

The quantities here are *compile-time* facts about a traced computation —
how many pool scatters an op lowers to — used to pin down the unified
PageStore's write amplification (ROADMAP: fused k+v row write => 3 scatters
per batch insert) and to assert the serving engine's step-level coalescing
(one batched insert per tick means a tick's insert path carries exactly the
scatter count of ONE `hashmap.insert`, independent of how many requests
contributed ops to the tick).
"""
from __future__ import annotations


def count_primitive(fn, prefix: str, *args) -> int:
    """Number of primitives whose name starts with ``prefix`` in fn's jaxpr,
    recursing into sub-jaxprs (jit/cond/scan/shard_map bodies).

    Used two ways: ``prefix='scatter'`` pins the unified PageStore's write
    amplification (3 pool scatters per batch insert), and
    ``prefix='shard_map'`` / ``prefix='all_to_all'`` pin the RLU mesh
    contract — one coalesced serving phase lowers to exactly ONE routed
    device call no matter how many requests or shards feed it.
    """
    import jax

    n = 0

    def visit(v):
        if hasattr(v, "jaxpr"):        # ClosedJaxpr
            walk(v.jaxpr)
        elif hasattr(v, "eqns"):       # Jaxpr
            walk(v)
        elif isinstance(v, (tuple, list)):   # e.g. cond/switch branches
            for x in v:
                visit(x)

    def walk(j):
        nonlocal n
        for eq in j.eqns:
            if eq.primitive.name.startswith(prefix):
                n += 1
            for v in eq.params.values():
                visit(v)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return n


def count_scatters(fn, *args) -> int:
    """Number of scatter primitives in fn's jaxpr (recursing into sub-jaxprs
    — the structural 'pool scatters per op' the ROADMAP tracks)."""
    return count_primitive(fn, "scatter", *args)


def primitive_shapes(fn, prefix: str, *args) -> list:
    """Output shapes (tuples) of every primitive whose name starts with
    ``prefix`` in fn's jaxpr, recursing into sub-jaxprs, in program order.

    Pins DATA-dependent compile-time structure: the two-pass routing tests
    trace the fused tick with differently-skewed batches of the SAME shape
    and assert the ``all_to_all`` buffer shapes changed — i.e. the routing
    capacity follows the measured skew, not the worst-case Q_local.
    """
    import jax

    shapes: list = []

    def visit(v):
        if hasattr(v, "jaxpr"):        # ClosedJaxpr
            walk(v.jaxpr)
        elif hasattr(v, "eqns"):       # Jaxpr
            walk(v)
        elif isinstance(v, (tuple, list)):   # e.g. cond/switch branches
            for x in v:
                visit(x)

    def walk(j):
        for eq in j.eqns:
            if eq.primitive.name.startswith(prefix):
                shapes.extend(tuple(o.aval.shape) for o in eq.outvars)
            for v in eq.params.values():
                visit(v)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return shapes
