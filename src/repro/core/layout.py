"""Unified PageStore: interleaved bucket-row layout, bit-plane packing,
fingerprint lane and stash (paper §2, §2.2, §2.4; Dash/IcebergHT).

The HashMem pool mirrors the paper's DRAM organization, where ONE row
activation exposes an entire bucket segment — keys *and* values — to the
subarray compare units:

  * page  == one subarray row: ``slots`` columns of interleaved key/value
    pairs, stored as a single ``(num_pages, slots, 2)`` uint32 array
    (lane 0 = key, lane 1 = value).  Opening a page (loading its row into
    VMEM) exposes the whole bucket segment in ONE fetch, exactly like a
    DRAM row activation — probes read the key AND its value from the same
    activated row, and mutations write both with a single fused scatter
    (``PageStore.write_slots``).  IcebergHT/Dash make the same argument for
    PM: co-locating a bucket's keys and payloads in one access unit is what
    makes probes single-access.
  * ``PageStore`` owns the pool plus all per-page bookkeeping: the optional
    column-oriented bit-planes, the overflow chain links (``page_next``),
    the per-page fill high-water marks and the ``pim_malloc`` bump pointer
    (``free_top``).  ``key_pages``/``val_pages`` remain available as thin
    lane views for callers that want the split layout.
  * The performance-optimized version stores keys **column-oriented as bit
    slices** (paper: "each row contains a single-bit slice from thousands of
    values").  ``pack_bitplanes`` produces that layout: plane j, word w holds
    bit j of keys at slots [32w, 32w+32).  A b-bit probe is then b bitwise
    vector ops over int32 lane words — element-parallel, bit-serial.
  * **Fingerprint lane** (``fp_bits > 0``, Dash §4): ``fprints`` holds the
    low ``fp_bits`` of an independent hash of each slot's key, packed with
    the SAME bit-plane machinery as ``planes`` — ``(num_pages, fp_bits,
    slots//32)``.  A probe scans this narrow lane first (fp_bits bitwise
    ops instead of a full row fetch) and activates the wide ``(slots, 2)``
    row only for pages holding a fingerprint match, dropping rows activated
    per probe toward 1 under skew.  ``write_slots``/``write_keys`` keep it
    in sync automatically; the invariant is
    ``unpack_bitplanes(fprints, fp_bits) == fingerprint(key_pages, fp_bits)``
    (EMPTY and TOMBSTONE sentinels are fingerprinted like any key — a probe
    for a user key simply never matches their fingerprints except as a
    bounded false positive, rejected by the full row compare).
  * **Stash** (``stash_slots > 0``, IcebergHT §3): a tiny ``(stash_slots,
    2)`` register-file of key/value pairs absorbing inserts that neither
    bucket choice could place.  It is deliberately NOT page-backed: probes
    compare it whole, in-register, with zero row activations.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hashing import EMPTY_KEY, fingerprint

U32 = jnp.uint32
I32 = jnp.int32

KEY_LANE = 0
VAL_LANE = 1


# ---------------------------------------------------------------------------
# PageStore: the one owner of the interleaved pool + page bookkeeping
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["pool", "planes", "page_next", "page_fill", "free_top",
                      "fprints", "stash", "stash_fill", "local_depth"],
         meta_fields=["key_bits", "fp_bits"])
@dataclass
class PageStore:
    """Interleaved page pool + per-page bookkeeping (one pytree).

    ``pool[p, s, KEY_LANE]`` is the key at slot s of page p and
    ``pool[p, s, VAL_LANE]`` its value — one row activation serves both.
    All mutations flow through ``write_slots`` (fused key+value scatter,
    keeping the bit-planes AND the fingerprint lane in sync) or the
    dedicated tombstone/link helpers.
    """

    pool: jax.Array               # (num_pages, slots, 2) uint32
    planes: Optional[jax.Array]   # (num_pages, key_bits, slots//32) | None
    page_next: jax.Array          # (num_pages,) int32, -1 terminal
    page_fill: jax.Array          # (num_pages,) int32 fill high-water mark
    free_top: jax.Array           # () int32 pim_malloc bump pointer
    key_bits: int                 # static: width of the bit-plane scan
    fprints: Optional[jax.Array] = None   # (num_pages, fp_bits, slots//32)
    stash: Optional[jax.Array] = None     # (stash_slots, 2) uint32 | None
    stash_fill: Optional[jax.Array] = None  # () int32 bump pointer | None
    local_depth: Optional[jax.Array] = None  # (num_pages,) int32 extendible
                                  # local depth, meaningful at group HEAD
                                  # pages (hashmap.py "extendible resize");
                                  # None when resize="rebuild"
    fp_bits: int = 0              # static: fingerprint width (0 = lane off)

    # -- thin split views (external callers / differential harness) --------
    @property
    def key_pages(self) -> jax.Array:
        return self.pool[..., KEY_LANE]

    @property
    def val_pages(self) -> jax.Array:
        return self.pool[..., VAL_LANE]

    @property
    def num_pages(self) -> int:
        return self.pool.shape[0]

    @property
    def slots(self) -> int:
        return self.pool.shape[1]

    # -- the fused write path ----------------------------------------------
    def write_slots(self, pages, slots_idx, keys, vals) -> "PageStore":
        """ONE pool scatter writes key and value into the same activated
        rows (out-of-range page => dropped, ``mode="drop"``); the bit-planes
        are maintained incrementally when present.  In-range (page, slot)
        pairs must be unique within the batch (bit-plane merge is additive).
        """
        kv = jnp.stack([keys.astype(U32), vals.astype(U32)], axis=-1)
        pool = self.pool.at[pages, slots_idx].set(kv, mode="drop")
        planes = self.planes
        if planes is not None:
            planes = update_bitplanes_batch(planes, pages, slots_idx,
                                            keys.astype(U32), self.key_bits)
        fprints = self.fprints
        if fprints is not None:
            fprints = update_bitplanes_batch(
                fprints, pages, slots_idx,
                fingerprint(keys.astype(U32), self.fp_bits), self.fp_bits)
        return dataclasses.replace(self, pool=pool, planes=planes,
                                   fprints=fprints)

    def write_keys(self, pages, slots_idx, keys,
                   plane_pages=None) -> "PageStore":
        """Key-lane-only scatter (tombstone writes): the value lane of the
        row is left untouched.  ``plane_pages`` optionally overrides the
        page ids used for the bit-plane update (delete dedups duplicate
        targets there)."""
        pool = self.pool.at[pages, slots_idx, KEY_LANE].set(
            keys.astype(U32), mode="drop")
        pp = pages if plane_pages is None else plane_pages
        planes = self.planes
        if planes is not None:
            planes = update_bitplanes_batch(planes, pp, slots_idx,
                                            keys.astype(U32), self.key_bits)
        fprints = self.fprints
        if fprints is not None:
            fprints = update_bitplanes_batch(
                fprints, pp, slots_idx,
                fingerprint(keys.astype(U32), self.fp_bits), self.fp_bits)
        return dataclasses.replace(self, pool=pool, planes=planes,
                                   fprints=fprints)

def empty_store(num_pages: int, slots: int, key_bits: int = 32,
                with_planes: bool = False, fp_bits: int = 0,
                stash_slots: int = 0,
                local_depth: Optional[int] = None) -> PageStore:
    """Fresh PageStore: every key EMPTY, every value 0, no chains.

    ``fp_bits > 0`` allocates the fingerprint lane (initialized to the
    fingerprint of EMPTY_KEY in every slot, matching the pool);
    ``stash_slots > 0`` allocates the stash (keys EMPTY, fill 0);
    ``local_depth`` (an int) allocates the extendible-hashing depth lane
    filled with that initial depth (= the table's global depth)."""
    pool = empty_pool(num_pages, slots)
    planes = pack_bitplanes(pool[..., KEY_LANE], key_bits) if with_planes \
        else None
    fprints = None
    if fp_bits > 0:
        fprints = pack_bitplanes(
            fingerprint(pool[..., KEY_LANE], fp_bits), fp_bits)
    stash = stash_fill = None
    if stash_slots > 0:
        stash = jnp.broadcast_to(jnp.array([EMPTY_KEY, 0], dtype=U32),
                                 (stash_slots, 2))
        stash_fill = jnp.asarray(0, dtype=I32)
    depths = None
    if local_depth is not None:
        depths = jnp.full((num_pages,), local_depth, dtype=I32)
    return PageStore(
        pool=pool,
        planes=planes,
        page_next=jnp.full((num_pages,), -1, dtype=I32),
        page_fill=jnp.zeros((num_pages,), dtype=I32),
        free_top=jnp.asarray(0, dtype=I32),
        key_bits=key_bits,
        fprints=fprints,
        stash=stash,
        stash_fill=stash_fill,
        local_depth=depths,
        fp_bits=fp_bits,
    )


def empty_pool(num_pages: int, slots: int) -> jax.Array:
    """(num_pages, slots, 2) interleaved pool: keys EMPTY, values 0.

    Built by broadcast (not a strided lane scatter) so bulk builds spend
    their scatter budget only on real writes."""
    row = jnp.array([EMPTY_KEY, 0], dtype=U32)
    return jnp.broadcast_to(row, (num_pages, slots, 2))


def interleave(key_pages, val_pages) -> jax.Array:
    """Zip split (P, S) key/value arrays into the (P, S, 2) pool layout."""
    return jnp.stack([key_pages.astype(U32), val_pages.astype(U32)], axis=-1)


# ---------------------------------------------------------------------------
# Bit-plane packing (the paper's column-oriented key layout)
# ---------------------------------------------------------------------------

def pack_bitplanes(key_pages, key_bits: int):
    """(P, S) uint32 keys -> (P, key_bits, S//32) uint32 bit-planes.

    Word layout: plane[p, j, w] bit i (LSB-first) = bit j of key_pages[p, 32w+i].
    """
    P, S = key_pages.shape
    assert S % 32 == 0, "slots must be a multiple of 32 for bit-plane packing"
    # (P, S, key_bits) bit j of each key
    j = jnp.arange(key_bits, dtype=U32)
    bits = (key_pages[:, :, None] >> j[None, None, :]) & U32(1)  # (P, S, b)
    bits = bits.transpose(0, 2, 1).reshape(P, key_bits, S // 32, 32)
    weights = (U32(1) << jnp.arange(32, dtype=U32))
    planes = jnp.sum(bits * weights[None, None, None, :], axis=-1, dtype=U32)
    return planes


def update_bitplanes_batch(planes, pages, slots_idx, new_keys, key_bits: int):
    """Batched incremental bit-plane maintenance for a set of slot writes.

    ``pages``/``slots_idx`` (B,) int32 name the written slots (out-of-range
    page => the update is dropped, matching ``.at[...].set(mode="drop")`` on
    the key lane); ``new_keys`` (B,) uint32 are the values written there.
    Each in-range (page, slot) pair must be unique within the batch: bits are
    merged with scatter-adds, which only act as OR when every added bit is
    distinct.
    """
    P, kb, W = planes.shape
    assert kb == key_bits
    word = (slots_idx // 32).astype(jnp.int32)
    bit = (slots_idx % 32).astype(U32)
    # per-(page, word) mask of rewritten lanes, then per-plane replacement bits
    clear = jnp.zeros((P, W), U32).at[pages, word].add(U32(1) << bit,
                                                       mode="drop")
    j = jnp.arange(key_bits, dtype=U32)
    kbits = (((new_keys.astype(U32)[:, None] >> j[None, :]) & U32(1))
             << bit[:, None])                                       # (B, kb)
    setb = jnp.zeros((P, kb, W), U32).at[pages, :, word].add(kbits, mode="drop")
    return (planes & ~clear[:, None, :]) | setb


def unpack_bitplanes(planes, key_bits: int):
    """Inverse of pack_bitplanes (for tests): (P, b, W) -> (P, 32W) uint32."""
    P, b, W = planes.shape
    assert b == key_bits
    i = jnp.arange(32, dtype=U32)
    bits = (planes[:, :, :, None] >> i[None, None, None, :]) & U32(1)  # (P,b,W,32)
    bits = bits.reshape(P, b, W * 32).transpose(0, 2, 1)               # (P,S,b)
    j = jnp.arange(key_bits, dtype=U32)
    return jnp.sum(bits * (U32(1) << j)[None, None, :], axis=-1, dtype=U32)
