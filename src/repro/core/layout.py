"""Bucket-per-row page layout + bit-plane packing (paper §2, §2.2).

The HashMem pool mirrors the paper's DRAM organization:

  * page  == one subarray row: ``slots`` columns of key/value pairs.
    Opening a page (loading its row into VMEM) exposes the whole bucket
    segment to the comparison units, exactly like a DRAM row activation.
  * The performance-optimized version stores keys **column-oriented as bit
    slices** (paper: "each row contains a single-bit slice from thousands of
    values").  ``pack_bitplanes`` produces that layout: plane j, word w holds
    bit j of keys at slots [32w, 32w+32).  A b-bit probe is then b bitwise
    vector ops over int32 lane words — element-parallel, bit-serial.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hashing import EMPTY_KEY

U32 = jnp.uint32


def empty_pool(num_pages: int, slots: int):
    """Key/value page pools initialized to EMPTY."""
    keys = jnp.full((num_pages, slots), EMPTY_KEY, dtype=U32)
    vals = jnp.zeros((num_pages, slots), dtype=U32)
    return keys, vals


def pack_bitplanes(key_pages, key_bits: int):
    """(P, S) uint32 keys -> (P, key_bits, S//32) uint32 bit-planes.

    Word layout: plane[p, j, w] bit i (LSB-first) = bit j of key_pages[p, 32w+i].
    """
    P, S = key_pages.shape
    assert S % 32 == 0, "slots must be a multiple of 32 for bit-plane packing"
    # (P, S, key_bits) bit j of each key
    j = jnp.arange(key_bits, dtype=U32)
    bits = (key_pages[:, :, None] >> j[None, None, :]) & U32(1)  # (P, S, b)
    bits = bits.transpose(0, 2, 1).reshape(P, key_bits, S // 32, 32)
    weights = (U32(1) << jnp.arange(32, dtype=U32))
    planes = jnp.sum(bits * weights[None, None, None, :], axis=-1, dtype=U32)
    return planes


def update_bitplanes_batch(planes, pages, slots_idx, new_keys, key_bits: int):
    """Batched incremental bit-plane maintenance for a set of slot writes.

    ``pages``/``slots_idx`` (B,) int32 name the written slots (out-of-range
    page => the update is dropped, matching ``.at[...].set(mode="drop")`` on
    the key pages); ``new_keys`` (B,) uint32 are the values written there.
    Each in-range (page, slot) pair must be unique within the batch: bits are
    merged with scatter-adds, which only act as OR when every added bit is
    distinct.
    """
    P, kb, W = planes.shape
    assert kb == key_bits
    word = (slots_idx // 32).astype(jnp.int32)
    bit = (slots_idx % 32).astype(U32)
    # per-(page, word) mask of rewritten lanes, then per-plane replacement bits
    clear = jnp.zeros((P, W), U32).at[pages, word].add(U32(1) << bit,
                                                       mode="drop")
    j = jnp.arange(key_bits, dtype=U32)
    kbits = (((new_keys.astype(U32)[:, None] >> j[None, :]) & U32(1))
             << bit[:, None])                                       # (B, kb)
    setb = jnp.zeros((P, kb, W), U32).at[pages, :, word].add(kbits, mode="drop")
    return (planes & ~clear[:, None, :]) | setb


def unpack_bitplanes(planes, key_bits: int):
    """Inverse of pack_bitplanes (for tests): (P, b, W) -> (P, 32W) uint32."""
    P, b, W = planes.shape
    assert b == key_bits
    i = jnp.arange(32, dtype=U32)
    bits = (planes[:, :, :, None] >> i[None, None, None, :]) & U32(1)  # (P,b,W,32)
    bits = bits.reshape(P, b, W * 32).transpose(0, 2, 1)               # (P,S,b)
    j = jnp.arange(key_bits, dtype=U32)
    return jnp.sum(bits * (U32(1) << j)[None, None, :], axis=-1, dtype=U32)
