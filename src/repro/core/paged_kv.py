"""Paged KV cache managed by a HashMem page table (DESIGN.md §3.1).

This is the paper's virtualization layer (§2.4-2.5) applied to serving:

  * a KV "page" holds ``page_tokens`` tokens of one sequence — the
    bucket-per-page mapping (logical bucket = (seq, block index)).
  * the page table is a real ``repro.core.hashmap.HashMem``: key =
    seq_id * MAX_BLOCKS + block, value = physical page id.  Allocation is
    ``pim_malloc`` from per-channel free lists; freeing a sequence writes
    tombstones (paper deletion semantics) and recycles the physical pages.
  * physical pages are spread across the mesh — the paper's §2.5
    optimization of spreading overflow pages "across different channels ...
    to enable the parallel probing of pages".  Decode attention is split-KV
    across channels with a log-sum-exp combine (flash-decoding semantics
    falling out of the paper's channel parallelism).

Pool layout (grouped): the flat page-pool dim is sharded jointly over ALL
mesh axes.  Device (batch-group g, channel m) owns physical pages
[flat*pps, (flat+1)*pps), flat = g*Dm + m.  Sequence b belongs to batch
group g(b) (its batch shard); logical page j of b lives on channel j mod Dm.
With no batch sharding (long-context B=1) every axis is a channel.

Inside jit, the resolved block table (the RLU command stream) is a dense
(B, n_pages) int32 array; the HashMem manager lives at the serving layer.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compat

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Pools
# ---------------------------------------------------------------------------

def init_pool(num_pages: int, page_tokens: int, kv_heads: int, head_dim: int,
              dtype=jnp.bfloat16):
    shape = (num_pages, page_tokens, kv_heads, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def _flat_index(axes: Sequence[str]) -> jax.Array:
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axes_size(axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= compat.axis_size(a)
    return n


# ---------------------------------------------------------------------------
# Local (single-device) paths
# ---------------------------------------------------------------------------

def append(k_pool, v_pool, block_table, pos, k_new, v_new):
    """Write one new token per sequence into its tail page (local pool)."""
    pt = k_pool.shape[1]
    j = pos // pt
    off = pos % pt
    page = jnp.take_along_axis(block_table, j[:, None], axis=1)[:, 0]
    k_pool = k_pool.at[page, off].set(k_new[:, 0])
    v_pool = v_pool.at[page, off].set(v_new[:, 0])
    return k_pool, v_pool


def _partial_decode(q, k, v, positions, pos, window):
    """Partial (per-channel) attention.  q (B,K,G,hd); k/v (B,T,K,hd);
    positions (B,T) absolute token positions (-1 = invalid).
    Returns (m, l, acc) for LSE combine."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgh,btkh->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    valid = (positions >= 0) & (positions <= pos[:, None])
    if window:
        valid &= positions > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(valid[:, None, None], jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return m, l, acc


def paged_decode_attention(q, k_pool, v_pool, block_table, pos, cfg):
    """Single-device decode attention (gather path)."""
    B, _, H, hd = q.shape
    K = k_pool.shape[2]
    G = H // K
    pt = k_pool.shape[1]
    qg = q.reshape(B, K, G, hd)
    n_pages = block_table.shape[1]
    k = k_pool[block_table].reshape(B, n_pages * pt, K, hd)
    v = v_pool[block_table].reshape(B, n_pages * pt, K, hd)
    positions = jnp.broadcast_to(jnp.arange(n_pages * pt), (B, n_pages * pt))
    m, l, acc = _partial_decode(qg, k, v, positions, pos, cfg.sliding_window)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Channel-parallel (inside shard_map over the WHOLE mesh)
# ---------------------------------------------------------------------------

def decode_attention_sharded(q, k_pool, v_pool, block_table, pos, cfg,
                             batch_axes: Sequence[str],
                             channel_axes: Sequence[str],
                             pages_per_shard: int):
    """q (B_loc,1,H,hd) local batch; pools are the LOCAL page slice;
    block_table (B_loc, n_pages) holds GLOBAL physical page ids."""
    B, _, H, hd = q.shape
    K = k_pool.shape[2]
    G = H // K
    pt = k_pool.shape[1]
    qg = q.reshape(B, K, G, hd)
    n_pages = block_table.shape[1]

    Dm = _axes_size(channel_axes)
    me_m = _flat_index(channel_axes)
    me_flat = _flat_index(tuple(batch_axes) + tuple(channel_axes))
    nl = max(n_pages // Dm, 1)

    # logical pages j ≡ me_m (mod Dm)
    bt_r = block_table[:, :nl * Dm].reshape(B, nl, Dm)
    local_bt = jnp.take_along_axis(
        bt_r, jnp.full((B, nl, 1), me_m, jnp.int32), axis=2)[..., 0]
    mine = (local_bt // pages_per_shard) == me_flat        # allocator guarantee
    slot = jnp.where(mine, local_bt % pages_per_shard, 0)
    k = k_pool[slot].reshape(B, nl * pt, K, hd)
    v = v_pool[slot].reshape(B, nl * pt, K, hd)
    j_log = jnp.arange(nl) * Dm + me_m
    positions = (j_log[:, None] * pt + jnp.arange(pt)[None, :])  # (nl, pt)
    positions = jnp.where(mine[:, :, None], positions[None], -1) \
        .reshape(B, nl * pt)
    m, l, acc = _partial_decode(qg, k, v, positions, pos, cfg.sliding_window)
    # LSE combine across channels only (batch axes hold distinct sequences)
    if channel_axes:
        M = m
        for a in channel_axes:
            M = jax.lax.pmax(M, a)
        r = jnp.exp(m - M)
        num = jax.lax.psum(acc * r[..., None], tuple(channel_axes))
        den = jax.lax.psum(l * r, tuple(channel_axes))
    else:
        num, den = acc, l
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def append_sharded(k_pool, v_pool, block_table, pos, k_new, v_new,
                   batch_axes: Sequence[str], channel_axes: Sequence[str],
                   pages_per_shard: int):
    """Owner-channel append.  All args local-batch views."""
    pt = k_pool.shape[1]
    me_flat = _flat_index(tuple(batch_axes) + tuple(channel_axes))
    j = pos // pt
    off = pos % pt
    page = jnp.take_along_axis(block_table, j[:, None], axis=1)[:, 0]
    mine = (page // pages_per_shard) == me_flat
    slot = jnp.where(mine, page % pages_per_shard, k_pool.shape[0])
    k_pool = k_pool.at[slot, off].set(k_new[:, 0], mode="drop")
    v_pool = v_pool.at[slot, off].set(v_new[:, 0], mode="drop")
    return k_pool, v_pool


def prefill_pages(k_pool, v_pool, block_table, k, v):
    """Scatter prefill KV (B,S,K,hd) into pages (local pool).  S must be a
    multiple of page_tokens; block_table (B, >=S/pt)."""
    B, S, K, hd = k.shape
    pt = k_pool.shape[1]
    n = S // pt
    kp = k.reshape(B, n, pt, K, hd)
    vp = v.reshape(B, n, pt, K, hd)
    bt = block_table[:, :n]
    k_pool = k_pool.at[bt].set(kp)
    v_pool = v_pool.at[bt].set(vp)
    return k_pool, v_pool


# ---------------------------------------------------------------------------
# Serving layer: the HashMem page-table manager (outside jit)
# ---------------------------------------------------------------------------

class PageTableManager:
    """Page-table = HashMem; pim_malloc = per-owner free-list arenas.

    Keys are seq_id * max_blocks + block_idx (uint32); values are physical
    page ids.  ``block_table`` resolves the dense in-jit table by PROBING
    the hashmap (through any backend, including the Pallas kernels).

    ``num_channels`` arenas follow the grouped layout: arena c owns physical
    ids [c*pps, (c+1)*pps).  ``alloc_seq(..., group=g)`` places logical page
    j in arena g*Dm + (j % Dm) — batch group g, channel j mod Dm.
    """

    MAX_BLOCKS = 1 << 12
    CHAIN_CHECK_EVERY = 4   # frees between compact_chain_len device walks

    def __init__(self, total_pages: int, num_channels: int = 1,
                 num_groups: int = 1, hashmem_cfg=None, backend: str = "ref",
                 compact_chain_len: int | None = None):
        import dataclasses

        from repro.configs.base import HashMemConfig
        from repro.core import hashmap

        arenas = num_channels * num_groups
        assert total_pages % arenas == 0
        self.Dm = num_channels
        self.groups = num_groups
        self.pps = total_pages // arenas
        self.total_pages = total_pages
        cfg = hashmem_cfg or HashMemConfig(
            num_buckets=max(64, total_pages // 4), slots_per_page=128,
            overflow_pages=max(64, total_pages // 8), max_chain=8,
            backend=backend)
        if compact_chain_len is not None:
            cfg = dataclasses.replace(cfg, compact_chain_len=compact_chain_len)
        self.cfg = cfg
        self.hm = hashmap.create(cfg)
        self.free = [list(range(c * self.pps, (c + 1) * self.pps))[::-1]
                     for c in range(arenas)]
        self.owned: dict[int, list[int]] = {}
        self.grow_events = 0
        self.compact_events = 0
        self._tombstones = 0        # host-side count; avoids device syncs
        self._frees_since_chain_check = 0   # throttles the device chain walk

    def _key(self, seq_id: int, block: int) -> int:
        assert block < self.MAX_BLOCKS
        return seq_id * self.MAX_BLOCKS + block

    def _return_pages(self, pages):
        for p in pages:
            self.free[p // self.pps].append(p)

    def alloc_seq(self, seq_id: int, n_blocks: int, group: int = 0) -> np.ndarray:
        return self.alloc_seqs([(seq_id, n_blocks, group)])[seq_id]

    def alloc_seqs(self, reqs) -> dict:
        """Coalesced allocation: ``reqs`` is [(seq_id, n_blocks, group), ...]
        — pages for ALL sequences are claimed from the arenas and their table
        entries land in ONE batched HashMem insert (the serving engine calls
        this once per tick, so page-table round trips stay O(1) in the number
        of admitted requests).  Returns {seq_id: (n_blocks,) int32 phys}."""
        from repro.core import hashmap
        from repro.core.hashing import validate_user_keys
        # decode-path key-domain guard (same shared check as the serving
        # engine's submit/preload): a seq-derived key reaching the reserved
        # pad/sentinel range would silently become routing padding/EMPTY —
        # checked BEFORE any page is claimed so a rejected request leaks
        # nothing.  Each request's largest key is at its last block.
        if reqs:
            validate_user_keys(
                np.asarray([self._key(s, max(n - 1, 0))
                            for s, n, _ in reqs], np.int64),
                where="page-table alloc")
        phys, keys, spans = [], [], []
        for seq_id, n_blocks, group in reqs:
            start = len(phys)
            for j in range(n_blocks):
                arena = self.free[group * self.Dm + j % self.Dm]
                if not arena:
                    self._return_pages(phys)        # no partial-alloc leak
                    raise MemoryError("pim_malloc: PR_ERROR (arena exhausted)")
                p = arena.pop()
                phys.append(p)
                keys.append(self._key(seq_id, j))
            spans.append((seq_id, start, len(phys)))
        if not phys:
            # nothing to insert, but zero-block sequences still get their
            # (empty) entries — alloc_seq(s, 0) keeps returning an empty
            # table rather than raising
            out = {}
            for seq_id, _, _ in spans:
                self.owned.setdefault(seq_id, [])
                out[seq_id] = np.empty((0,), np.int32)
            return out
        if self.cfg.auto_grow:
            # arena exhaustion / chain overflow in the page table triggers a
            # resize instead of a dropped allocation (hashmap.py docstring)
            before = self.hm.config.num_pages
            self.hm, ok = hashmap.insert_auto(
                self.hm, jnp.asarray(keys, jnp.uint32),
                jnp.asarray(phys, jnp.uint32))
            if self.hm.config.num_pages != before:   # arena REBUILT (an
                # extendible directory doubling keeps num_pages — and every
                # tombstone — in place, so it must not reset the count)
                self.grow_events += 1
                self.cfg = self.hm.config
                self._tombstones = 0                # grow rebuild dropped them
        else:
            self.hm, ok = hashmap.insert(
                self.hm, jnp.asarray(keys, jnp.uint32),
                jnp.asarray(phys, jnp.uint32))
        if not bool(jnp.all(ok)):
            self._return_pages(phys)
            raise MemoryError("page-table insert failed (PR_ERROR)")
        out = {}
        for seq_id, a, b in spans:
            self.owned.setdefault(seq_id, []).extend(phys[a:b])
            out[seq_id] = np.asarray(phys[a:b], np.int32)
        return out

    def block_table(self, seq_ids, n_blocks: int) -> np.ndarray:
        """Resolve (B, n_blocks) dense table by probing the HashMem."""
        from repro.core import hashmap
        B = len(seq_ids)
        keys = np.asarray([[self._key(s, j) for j in range(n_blocks)]
                           for s in seq_ids], np.uint32).reshape(-1)
        vals, found = hashmap.probe(self.hm, jnp.asarray(keys))
        vals = np.asarray(vals).astype(np.int32)
        found = np.asarray(found)
        vals[~found] = 0  # unallocated blocks -> page 0 (masked by pos in-attn)
        return vals.reshape(B, n_blocks)

    def free_seq(self, seq_id: int):
        """Tombstone the table entries (paper §2.5) and recycle pages."""
        self.free_seqs([seq_id])

    def free_seqs(self, seq_ids):
        """Coalesced free: every finished sequence's table entries are
        tombstoned in ONE batched HashMem delete (one call per engine tick,
        however many requests completed in it)."""
        from repro.core import hashmap
        keys, pages = [], []
        for seq_id in seq_ids:
            own = self.owned.pop(seq_id, [])
            keys.extend(self._key(seq_id, j) for j in range(len(own)))
            pages.extend(own)
        if not pages:
            return
        self.hm, _ = hashmap.delete(self.hm, jnp.asarray(keys, jnp.uint32))
        # every owned key was inserted, so every delete tombstones one slot;
        # counting host-side avoids a device reduction+sync per free
        self._tombstones += len(keys)
        self._return_pages(pages)
        self.maybe_compact()

    def maybe_compact(self):
        """Reclaim tombstoned page-table slots (the paper's §2.5 'wasted
        space') on either of two triggers:

          * GLOBAL: tombstones exceed ``compact_tombstone_frac`` of capacity
            (long-lived serving would otherwise grow chains without bound);
          * CHAIN (``compact_chain_len`` > 0): any bucket chain exceeds that
            many pages while tombstones exist.  Skewed delete streams pile
            tombstoned pages onto a few hot chains — per-probe RLU command
            depth degrades long before the global fraction trips.  The chain
            walk is a device computation + host sync, so it is throttled to
            every ``CHAIN_CHECK_EVERY`` checks (tombstone counting stays
            pure host-side, see __init__).

        Called from every free AND from the serving engine's tick clock
        (:meth:`tick`) — a long-running skewed tenant that stops freeing
        still gets its accumulated tombstones reclaimed.
        """
        from repro.core import hashmap
        cfg = self.hm.config
        trigger = hashmap.compact_due(self.hm, self._tombstones, chain=False)
        if (not trigger and cfg.compact_chain_len > 0
                and self._tombstones > 0):
            self._frees_since_chain_check += 1
            if self._frees_since_chain_check >= self.CHAIN_CHECK_EVERY:
                self._frees_since_chain_check = 0
                trigger = hashmap.compact_due(self.hm, self._tombstones,
                                              fraction=False)
        if trigger:
            self.hm = hashmap.compact(self.hm)
            self.compact_events += 1
            self._tombstones = 0
            self._frees_since_chain_check = 0

    def tick(self):
        """Engine-tick maintenance hook: re-run the compaction triggers on
        the tick clock rather than only on frees.  Before this hook existed,
        ``maybe_compact`` ran only inside :meth:`free_seq` — a tenant whose
        frees stopped (but whose earlier deletes left tombstones on hot
        chains) never compacted.  The decode loop in launch/serve.py calls
        this once per step."""
        self.maybe_compact()

    def live_pages(self) -> int:
        return sum(len(v) for v in self.owned.values())
