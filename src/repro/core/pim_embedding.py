"""Hash/dictionary-encoded embedding lookups through HashMem (DESIGN.md §3.3).

Two production patterns from the paper's §4.1.1 contract ("string values ...
dictionary-encoded into numerical values to be used in HashMem"):

  * ``DictionaryVocab``: a HashMem mapping raw feature keys (dictionary-
    encoded uint32) -> dense row ids; ``encode`` probes (through any backend,
    incl. the Pallas kernels) and ``lookup`` gathers embedding rows.  Unknown
    keys map to a learned OOV row — the not-found flag from the probe IS the
    OOV signal.
  * ``qr_embedding``: the quotient-remainder trick (Shi et al. 2019) for
    huge vocabularies: row = E_q[h // Q] + E_r[h % Q]; the hash is the
    paper's hash family (murmur3 finisher).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.core.hashing import HASH_FNS


class DictionaryVocab:
    """key -> row-id dictionary backed by a HashMem (probe = paper §2.5)."""

    def __init__(self, keys: np.ndarray, cfg: HashMemConfig | None = None):
        n = len(keys)
        self.cfg = cfg or HashMemConfig(
            num_buckets=max(64, 1 << int(np.ceil(np.log2(max(n, 1) / 256 + 1)))),
            slots_per_page=512,
            overflow_pages=max(64, n // 256),
            max_chain=8, backend="ref")
        rows = jnp.arange(n, dtype=jnp.uint32)
        self.hm = hashmap.build(self.cfg, jnp.asarray(keys, jnp.uint32), rows)
        self.size = n

    def encode(self, raw_keys, backend=None):
        """raw (..,) uint32 -> (row_ids (..,) int32, found (..,) bool);
        not-found -> row self.size (the OOV row)."""
        shape = raw_keys.shape
        rows, found = hashmap.probe(self.hm, raw_keys.reshape(-1),
                                    backend=backend)
        rows = jnp.where(found, rows, jnp.uint32(self.size)).astype(jnp.int32)
        return rows.reshape(shape), found.reshape(shape)

    def lookup(self, table, raw_keys, backend=None):
        """table ((size+1), d) with OOV row last -> embeddings (.., d)."""
        rows, _ = self.encode(raw_keys, backend=backend)
        return table[rows]


def qr_embedding(params, ids, num_rows: int, hash_fn: str = "murmur3_fmix"):
    """Quotient-remainder hash embedding.  params: {'q': (R_q, d),
    'r': (R_r, d)} with R_q = ceil(num_rows / R_r)."""
    h = HASH_FNS[hash_fn](ids.astype(jnp.uint32)) % jnp.uint32(num_rows)
    r_r = params["r"].shape[0]
    return params["q"][(h // r_r).astype(jnp.int32)] + \
        params["r"][(h % r_r).astype(jnp.int32)]


def init_qr(key, num_rows: int, d: int, r_r: int = 4096):
    kq, kr = jax.random.split(key)
    r_q = (num_rows + r_r - 1) // r_r
    return {"q": jax.random.normal(kq, (r_q, d)) * 0.02,
            "r": jax.random.normal(kr, (r_r, d)) * 0.02}
