"""Backend dispatch for HashMem probes (ref / area / perf / bitserial)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as kref


def probe_pages(hm, queries, pages, backend: str):
    """Dispatch a resolved probe (RLU command stream) to a compare backend."""
    if backend == "ref":
        return kref.probe_pages_ref(hm.key_pages, hm.val_pages, queries, pages)
    if backend == "perf":
        return ops.probe_perf(hm.key_pages, hm.val_pages, queries, pages)
    if backend == "area":
        return ops.probe_area(hm.key_pages, hm.val_pages, queries, pages)
    if backend == "bitserial":
        if hm.planes is None:
            raise ValueError("bitserial backend requires planes (backend='bitserial' at build)")
        return ops.probe_bitserial(hm.planes, hm.val_pages, queries, pages,
                                   key_bits=hm.config.key_bits)
    raise ValueError(f"unknown probe backend {backend!r}")
