"""Backend dispatch for HashMem probes (ref / area / perf / bitserial).

Every backend consumes the unified PageStore's interleaved (P, S, 2) pool:
one activated row per chain step carries the keys to compare AND the value
to return (paper §2.2/§2.4 row-buffer semantics).

The (Q, C) page schedule may contain -1 holes ANYWHERE, not just as tail
padding: the fingerprint pre-pass (hashmap._fp_filter) blanks pages whose
fingerprint lane holds no match, and the displaced resolve blanks the H2
chain head when it aliases the H1 direct page.  The Pallas backends turn
interior holes into row-buffer hits via the forward-filled fetch index
(kernels/ref.fill_fetch_pages); the ref oracle simply masks them.

Extendible resize (config.resize="extendible") is INVISIBLE here: the
bucket_head gather in hashmap.resolve_pages_by_bucket already IS the
extendible directory indirection (with pow2 num_buckets the bucket id is
the low-bits hash prefix = directory index, and directory entries aliasing
one group share the same chain head).  A probe under extendible mode costs
exactly the same one head gather + chain walk — no extra row activation —
so all four backends run unchanged through splits and directory doublings."""
from __future__ import annotations

from repro.kernels import ops
from repro.kernels import ref as kref


def probe_pages(hm, queries, pages, backend: str):
    """Dispatch a resolved probe (RLU command stream) to a compare backend."""
    pool = hm.store.pool
    if backend == "ref":
        return kref.probe_pages_ref(pool, queries, pages)
    if backend == "perf":
        return ops.probe_perf(pool, queries, pages)
    if backend == "area":
        return ops.probe_area(pool, queries, pages)
    if backend == "bitserial":
        if hm.planes is None:
            raise ValueError("bitserial backend requires planes (backend='bitserial' at build)")
        return ops.probe_bitserial(hm.planes, pool, queries, pages,
                                   key_bits=hm.config.key_bits)
    raise ValueError(f"unknown probe backend {backend!r}")
