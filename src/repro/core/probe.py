"""Backend dispatch for HashMem probes (ref / area / perf / bitserial).

Every backend consumes the unified PageStore's interleaved (P, S, 2) pool:
one activated row per chain step carries the keys to compare AND the value
to return (paper §2.2/§2.4 row-buffer semantics).

The (Q, C) page schedule may contain -1 holes ANYWHERE, not just as tail
padding: the fingerprint pre-pass (hashmap._fp_filter) blanks pages whose
fingerprint lane holds no match, and the displaced resolve blanks the H2
chain head when it aliases the H1 direct page.  The Pallas backends turn
interior holes into row-buffer hits via the forward-filled fetch index
(kernels/ref.fill_fetch_pages); the ref oracle simply masks them."""
from __future__ import annotations

from repro.kernels import ops
from repro.kernels import ref as kref


def probe_pages(hm, queries, pages, backend: str):
    """Dispatch a resolved probe (RLU command stream) to a compare backend."""
    pool = hm.store.pool
    if backend == "ref":
        return kref.probe_pages_ref(pool, queries, pages)
    if backend == "perf":
        return ops.probe_perf(pool, queries, pages)
    if backend == "area":
        return ops.probe_area(pool, queries, pages)
    if backend == "bitserial":
        if hm.planes is None:
            raise ValueError("bitserial backend requires planes (backend='bitserial' at build)")
        return ops.probe_bitserial(hm.planes, pool, queries, pages,
                                   key_bits=hm.config.key_bits)
    raise ValueError(f"unknown probe backend {backend!r}")
