"""RLU: orchestration between probe/mutation requests and HashMem shards.

Single-device: the RLU resolves each probe key to its page chain (the
"command stream", hashmap.resolve_pages) and issues it to a compare backend.

Multi-device ("channel-level parallelism", paper §6 — future work there,
IMPLEMENTED here): buckets are partitioned across a mesh axis the way the
paper spreads pages "across different channels and ranks ... to enable the
parallel probing of pages".  One global hash h(key) defines the routing;
two routers are supported (``shard_by``):

    "mod"       owner = h mod D,                  local bucket = (h div D) mod B
    "highbits"  owner = ((h >> 16) * D) >> 16,    local bucket = h mod B

"mod" is the original channel split; "highbits" is the fastrange split over
the hash's top 16 bits (any D, not just powers of two; pure uint32
arithmetic — the container's jax runs without x64) whose local bucket is
the plain ``hash_to_bucket`` assignment over the LOW bits — so a
"highbits" shard is just an ordinary HashMem whose keys happen to route to
it, and the default ``hashmap.grow`` rebucketing works per shard
unchanged.  The serving engine uses "highbits" for its mesh-backed shards.

Requests are routed to owners with ``all_to_all``, executed locally
(probe with the configured kernel backend; delete/insert with the
vectorized mutation engine), and routed back — the TPU ICI plays the role
of the paper's memory-channel fan-out.  ``probe_sharded`` /
``delete_sharded`` / ``insert_mesh`` are each ONE cached-jitted shard_map
call per invocation: a serving tick's whole coalesced phase crosses the
host<->mesh boundary once, no matter how many shards participate.

Every shard is a full HashMem over the unified PageStore (one interleaved
(P, S, 2) pool pytree per shard), so stacking shards for the mesh, the
synchronized-growth insert path and the local kernel probes all move ONE
pool leaf per shard instead of split key/value pairs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.core.hashing import EMPTY_KEY, HASH_FNS
from repro.core.compat import shard_map

U32 = jnp.uint32
I32 = jnp.int32

# Routing pad: below every sentinel, above every workload/tenant-folded key
# (kv_synth keeps raw keys < 0xFFFFFFF0 and tenancy.py reserves the top
# tenant id), so a padded routing slot probes/deletes nothing and an insert
# treats it as invalid — shared with the serving engine's batch pad.
ROUTE_PAD = np.uint32(0xFFFFFFF0)

SHARD_ROUTERS = ("mod", "highbits")


def _global_hash(keys, cfg: HashMemConfig):
    return HASH_FNS[cfg.hash_fn](keys.astype(U32), cfg.salt)


def _owner_from_hash(h, num_shards: int, shard_by: str):
    """THE owner formula (jnp) — single definition shared by owner_of and
    owner_and_local_bucket so a router change can't split routing between
    the build path and the per-phase calls."""
    if shard_by == "highbits":
        return (((h >> U32(16)) * U32(num_shards)) >> U32(16)).astype(I32)
    assert shard_by == "mod", shard_by
    return (h % U32(num_shards)).astype(I32)


def owner_of(keys, cfg: HashMemConfig, num_shards: int,
             shard_by: str = "mod"):
    """(N,) keys -> (N,) int32 owner shard ids under the chosen router."""
    return _owner_from_hash(_global_hash(keys, cfg), num_shards, shard_by)


def owner_of_np(keys, cfg: HashMemConfig, num_shards: int,
                shard_by: str = "mod") -> np.ndarray:
    """Host-side (numpy) mirror of ``owner_of`` — one vectorized call per
    serving phase partitions a whole coalesced batch without touching the
    device (see tests/test_hashing.py for the jnp<->np equivalence check)."""
    k = np.asarray(keys, np.uint32)
    if cfg.hash_fn == "murmur3_fmix":
        h = k ^ np.uint32(cfg.salt)
        h = h ^ (h >> np.uint32(16))
        h = h * np.uint32(0x85EBCA6B)
        h = h ^ (h >> np.uint32(13))
        h = h * np.uint32(0xC2B2AE35)
        h = h ^ (h >> np.uint32(16))
    elif cfg.hash_fn == "mult_shift":
        h = (k * np.uint32(2654435761)) ^ np.uint32(cfg.salt)
    else:                                   # identity
        h = k
    if shard_by == "highbits":
        return (((h >> np.uint32(16)) * np.uint32(num_shards))
                >> np.uint32(16)).astype(np.int32)
    assert shard_by == "mod", shard_by
    return (h % np.uint32(num_shards)).astype(np.int32)


def owner_and_local_bucket(keys, cfg: HashMemConfig, num_shards: int,
                           shard_by: str = "mod"):
    h = _global_hash(keys, cfg)
    owner = _owner_from_hash(h, num_shards, shard_by)
    if shard_by == "highbits":
        local = (h % U32(cfg.num_buckets)).astype(I32)
    else:
        local = ((h // U32(num_shards)) % U32(cfg.num_buckets)).astype(I32)
    return owner, local


def build_sharded(cfg: HashMemConfig, keys, vals, num_shards: int,
                  shard_by: str = "mod"):
    """Build per-shard HashMems; returns a stacked pytree with leading axis
    num_shards (shard i's arrays at index i), ready to shard over 'model'.

    cfg.num_buckets is the PER-SHARD bucket count.
    """
    owner, local = owner_and_local_bucket(keys, cfg, num_shards, shard_by)
    shards = []
    for d in range(num_shards):
        m = owner == d
        # density: route shard-d keys to front; pad with EMPTY (never probed)
        idx = jnp.argsort(~m)  # shard-d keys first
        k = jnp.where(m[idx], keys[idx].astype(U32), EMPTY_KEY)
        v = jnp.where(m[idx], vals[idx].astype(U32), U32(0))
        b = jnp.where(m[idx], local[idx], 0)
        # EMPTY keys land in bucket 0 but as EMPTY they never match a probe;
        # they do consume slots, so size the scaled config accordingly.
        shards.append(hashmap.build_with_buckets(cfg, k, v, b))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)


def _local_bucket_fn(num_shards: int, shard_by: str = "mod"):
    """bucket_fn for hashmap.grow/insert on one shard: re-derive the local
    bucket from the global hash under the (possibly grown) shard config."""
    def fn(keys, cfg: HashMemConfig):
        h = HASH_FNS[cfg.hash_fn](keys.astype(U32), cfg.salt)
        if shard_by == "highbits":
            return (h % U32(cfg.num_buckets)).astype(I32)
        return ((h // U32(num_shards)) % U32(cfg.num_buckets)).astype(I32)
    return fn


def insert_sharded(hm_stacked, keys, vals, cfg: HashMemConfig,
                   num_shards: int, max_grows: int = 4,
                   shard_by: str = "mod", max_splits: int = 256,
                   events: Optional[dict] = None):
    """Host-level routed insert into the stacked shard pytree.

    Keys are routed to their owner shard (same global-hash split as
    build_sharded) and batch-inserted with the vectorized engine.  When a
    shard reports PR_ERROR and cfg.auto_grow is set, the repair depends on
    ``cfg.resize``:

      * "rebuild" — ALL shards grow by the same factor (the stacked pytree
        must stay shape-homogeneous to remain shardable over the mesh axis)
        and the failed elements retry.
      * "extendible" — the failed GROUPS on the failed shards split
        (hashmap.split_group): a split is shape-preserving, so it is a
        purely LOCAL per-shard mutation — the other shards' pytree leaves
        are untouched and stacking stays homogeneous.  Only a directory
        doubling (bucket_head reallocates, cfg.num_buckets changes) must be
        synchronized across all shards, and it moves no slot data on any of
        them.  A split the arena/chain bound refuses falls back to a
        synchronized grow() rebuild.

    Returns (hm_stacked', ok (N,) bool, cfg').  cfg' differs from cfg after
    growth/doubling; pass it to subsequent probe_sharded/insert_sharded
    calls.  ``events`` (optional dict) accumulates "splits"/"doublings"/
    "rebuilds" counts.
    """
    keys = jnp.asarray(keys).astype(U32)
    vals = jnp.asarray(vals).astype(U32)
    n = keys.shape[0]
    owner = owner_of(keys, cfg, num_shards, shard_by)         # owner is
    owner_np = np.asarray(owner)                              # grow-invariant
    bfn = _local_bucket_fn(num_shards, shard_by)
    shards = [jax.tree.map(lambda x, d=d: x[d], hm_stacked)
              for d in range(num_shards)]
    extendible = cfg.resize == "extendible"

    def _bump(k):
        if events is not None:
            events[k] = events.get(k, 0) + 1

    ok = np.zeros((n,), bool)
    remaining = {d: np.nonzero(owner_np == d)[0] for d in range(num_shards)}
    grows = splits = 0
    while True:
        any_fail = False
        failed_buckets: dict = {}
        for d in range(num_shards):
            idx = remaining[d]
            if idx.size == 0:
                continue
            kd, vd = keys[idx], vals[idx]
            bd = bfn(kd, shards[d].config)
            hm_d, ok_d = hashmap.insert_with_buckets(shards[d], kd, vd, bd)
            shards[d] = hm_d
            ok_np = np.asarray(ok_d)
            ok[idx[ok_np]] = True
            remaining[d] = idx[~ok_np]
            if remaining[d].size:
                any_fail = True
                failed_buckets[d] = np.unique(np.asarray(bd)[~ok_np])
        if not any_fail or not cfg.auto_grow:
            break
        rebuild = not extendible
        if extendible and splits < max_splits:
            # split the refused groups in place — local, shape-preserving
            need_double = False
            progressed = False
            for d, bks in failed_buckets.items():
                for b0 in bks:
                    hm2, status = hashmap.split_group(shards[d], int(b0),
                                                      bucket_fn=bfn)
                    if status == "ok":
                        shards[d] = hm2
                        splits += 1
                        progressed = True
                        _bump("splits")
                    elif status == "need_double":
                        need_double = True
                    else:                         # "full" | "stuck"
                        rebuild = True
            if need_double and not rebuild:
                doubled = [hashmap.double_directory(s) for s in shards]
                if all(x is not None for x in doubled):
                    shards = doubled            # synchronized pointer copy
                    progressed = True
                    _bump("doublings")
                else:                           # arena can't cede pages
                    rebuild = True
            if not progressed and not rebuild:
                rebuild = True                  # nothing moved: escalate
        elif extendible:
            rebuild = True                      # split budget exhausted
        if rebuild:
            if grows >= max_grows:
                break
            # synchronized growth keeps every shard the same shape
            shards = [hashmap.grow(s, bucket_fn=bfn) for s in shards]
            grows += 1
            _bump("rebuilds")

    hm_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
    return hm_stacked, jnp.asarray(ok), shards[0].config


def _local_probe(hm_local, queries, cfg: HashMemConfig, num_shards: int,
                 shard_by: str = "mod"):
    _, local_bucket = owner_and_local_bucket(queries, cfg, num_shards,
                                             shard_by)
    # full probe pipeline per shard: displaced resolve + fingerprint filter
    # + backend + stash, so the fused tick_mesh megakernel (which runs this
    # inside its single shard_map) probes fingerprints and the stash
    # in-kernel too
    return hashmap.probe_with_buckets(hm_local, queries, local_bucket)


class _Route:
    """Owner-routing bookkeeping for one shard's local queries: the send
    buffer layout (stable argsort keeps intra-owner batch order, which is
    what preserves duplicate-key FIFO semantics end to end) plus the gather
    indices that un-route results.

    ``drop_invalid=True`` (the fused-tick path) excludes entries equal to
    ``pad`` from routing entirely: they get an out-of-range owner, are
    dropped from the send scatter, and never consume per-(src,dst)
    capacity — which is what lets the two-pass scheme set ``c`` to the
    measured max VALID count instead of Q_local.  Their gathered-back
    results are masked to 0/False."""

    def __init__(self, q_local, owner, num_shards: int, c: int, pad,
                 drop_invalid: bool = False):
        qn = q_local.shape[0]
        self.c = c
        self.num_shards = num_shards
        self.drop_invalid = drop_invalid
        q_local = q_local.astype(U32)
        if drop_invalid:
            self.valid = q_local != U32(pad)
            owner = jnp.where(self.valid, owner, I32(num_shards))
        self.order = jnp.argsort(owner)          # stable
        self.o_sorted = owner[self.order]
        q_sorted = q_local[self.order]
        # position within each owner group
        start = jnp.searchsorted(self.o_sorted, self.o_sorted, side="left")
        self.pos = jnp.arange(qn, dtype=I32) - start.astype(I32)
        self.overflow = self.pos >= c
        send = jnp.full((num_shards, c), pad, dtype=U32)
        if drop_invalid:
            # out-of-range rows (invalid) and pos >= c (overflow) both drop
            self.send = send.at[self.o_sorted, self.pos].set(
                q_sorted, mode="drop")
        else:
            self.send = send.at[self.o_sorted,
                                jnp.minimum(self.pos, c - 1)].set(
                jnp.where(self.overflow, pad, q_sorted))
        self.inv = jnp.argsort(self.order)

    def counts(self):
        """(num_shards,) int32: valid local queries per destination shard —
        the payload of the two-pass count exchange (drop_invalid only)."""
        assert self.drop_invalid
        return jnp.bincount(self.o_sorted, length=self.num_shards + 1)[
            :self.num_shards].astype(I32)

    def send_aux(self, x_local, num_shards: int, fill):
        """Route a second per-query array (e.g. insert values) the same way."""
        xs = x_local[self.order].astype(U32)
        send = jnp.full((num_shards, self.c), fill, dtype=U32)
        if self.drop_invalid:
            return send.at[self.o_sorted, self.pos].set(xs, mode="drop")
        return send.at[self.o_sorted, jnp.minimum(self.pos, self.c - 1)].set(
            jnp.where(self.overflow, fill, xs))

    def gather_back(self, back, mask_overflow: bool = False):
        """(num_shards, c) routed-back results -> original query order."""
        out = back[jnp.minimum(self.o_sorted, self.num_shards - 1),
                   jnp.minimum(self.pos, self.c - 1)]
        if mask_overflow:
            out = out & ~self.overflow
        if self.drop_invalid:
            out = jnp.where(self.valid[self.order], out,
                            jnp.zeros((), out.dtype))
        return out[self.inv]


# jitted shard_map'd phase calls, cached per (kind, mesh, axis, shard_by,
# cfg, cap) so a serving engine's hot loop reuses ONE compiled executable
# per phase per batch shape instead of re-tracing the shard_map every tick.
_sharded_call_cache: dict = {}


def _sharded_call(kind: str, mesh, cfg: HashMemConfig, axis: str,
                  shard_by: str, cap):
    key = (kind, mesh, cfg, axis, shard_by, cap)
    fn = _sharded_call_cache.get(key)
    if fn is None:
        num_shards = mesh.shape[axis]
        builder = {"probe": _probe_shard_fn, "delete": _delete_shard_fn,
                   "insert": _insert_shard_fn, "tick": _tick_shard_fn}[kind]
        shard_fn, n_in, n_out = builder(cfg, num_shards, axis, shard_by, cap)
        fn = jax.jit(shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(axis),) * n_in,
            out_specs=(P(axis),) * n_out,
            check_vma=False,
        ))
        _sharded_call_cache[key] = fn
    return fn


def _probe_shard_fn(cfg, num_shards, axis, shard_by, cap):
    def shard_fn(hm_stacked_local, q_local):
        hm_local = jax.tree.map(lambda x: x[0], hm_stacked_local)
        c = cap or q_local.shape[0]
        owner, _ = owner_and_local_bucket(q_local, cfg, num_shards, shard_by)
        rt = _Route(q_local, owner, num_shards, c, EMPTY_KEY)
        # route to owners: recv[s] = what shard s sent to me
        recv = jax.lax.all_to_all(rt.send, axis, 0, 0, tiled=False)
        rv, rf = _local_probe(hm_local, recv.reshape(-1), cfg, num_shards,
                              shard_by)
        back_v = jax.lax.all_to_all(rv.reshape(num_shards, c), axis, 0, 0,
                                    tiled=False)
        back_f = jax.lax.all_to_all(rf.reshape(num_shards, c), axis, 0, 0,
                                    tiled=False)
        return rt.gather_back(back_v), rt.gather_back(back_f,
                                                      mask_overflow=True)
    return shard_fn, 2, 2


def probe_sharded(mesh, hm_stacked, queries, cfg: HashMemConfig,
                  axis: str = "model", cap: Optional[int] = None,
                  shard_by: str = "mod"):
    """Channel-parallel probe: queries (Q,) sharded over `axis`.

    cap = per-(src,dst) routing capacity; None -> Q_local (always sufficient).
    Returns (values (Q,), found (Q,)) with the same sharding as queries.
    """
    fn = _sharded_call("probe", mesh, cfg, axis, shard_by, cap)
    return fn(hm_stacked, queries)


def _delete_shard_fn(cfg, num_shards, axis, shard_by, cap):
    def shard_fn(hm_stacked_local, q_local):
        hm_local = jax.tree.map(lambda x: x[0], hm_stacked_local)
        c = cap or q_local.shape[0]
        owner = owner_of(q_local, cfg, num_shards, shard_by)
        rt = _Route(q_local, owner, num_shards, c, jnp.uint32(ROUTE_PAD))
        recv = jax.lax.all_to_all(rt.send, axis, 0, 0, tiled=False)
        flat = recv.reshape(-1)
        _, lb = owner_and_local_bucket(flat, cfg, num_shards, shard_by)
        # ROUTE_PAD never matches a stored row -> found=False, no write
        hm2, found = hashmap.delete_with_buckets(hm_local, flat, lb)
        back_f = jax.lax.all_to_all(found.reshape(num_shards, c), axis, 0, 0,
                                    tiled=False)
        hm_out = jax.tree.map(lambda x: x[None], hm2)
        return hm_out, rt.gather_back(back_f, mask_overflow=True)
    return shard_fn, 2, 2


def delete_sharded(mesh, hm_stacked, keys, cfg: HashMemConfig,
                   axis: str = "model", cap: Optional[int] = None,
                   shard_by: str = "mod"):
    """Channel-parallel batched tombstone delete: ONE shard_map call routes
    every key to its owner shard, deletes locally, and routes the found
    mask back.  Returns (hm_stacked', found (Q,)).  Mirrors
    ``hashmap.delete`` semantics per owner shard (duplicate queries resolve
    to one removal)."""
    fn = _sharded_call("delete", mesh, cfg, axis, shard_by, cap)
    return fn(hm_stacked, keys)


def _insert_shard_fn(cfg, num_shards, axis, shard_by, cap):
    def shard_fn(hm_stacked_local, q_local, v_local):
        hm_local = jax.tree.map(lambda x: x[0], hm_stacked_local)
        c = cap or q_local.shape[0]
        owner, _ = owner_and_local_bucket(q_local, cfg, num_shards, shard_by)
        rt = _Route(q_local, owner, num_shards, c, jnp.uint32(ROUTE_PAD))
        recv_k = jax.lax.all_to_all(rt.send, axis, 0, 0, tiled=False)
        recv_v = jax.lax.all_to_all(
            rt.send_aux(v_local, num_shards, jnp.uint32(0)), axis, 0, 0,
            tiled=False)
        flat_k = recv_k.reshape(-1)
        valid = flat_k != jnp.uint32(ROUTE_PAD)
        _, lb = owner_and_local_bucket(flat_k, cfg, num_shards, shard_by)
        hm2, ok = hashmap.insert_with_buckets(hm_local, flat_k,
                                              recv_v.reshape(-1), lb,
                                              valid=valid)
        back_ok = jax.lax.all_to_all(ok.reshape(num_shards, c), axis, 0, 0,
                                     tiled=False)
        hm_out = jax.tree.map(lambda x: x[None], hm2)
        return hm_out, rt.gather_back(back_ok, mask_overflow=True)
    return shard_fn, 3, 2


def insert_mesh(mesh, hm_stacked, keys, vals, cfg: HashMemConfig,
                axis: str = "model", cap: Optional[int] = None,
                shard_by: str = "mod"):
    """Channel-parallel FIXED-ARENA batched insert: one shard_map call
    routes keys/values to owner shards and appends with the vectorized
    mutation engine.  Returns (hm_stacked', ok (Q,)).

    ok=False elements were refused (PR_ERROR: arena/chain bound) — shapes
    cannot change inside shard_map, so growth is the caller's host-level
    fallback (``insert_sharded``, which keeps all shards shape-homogeneous).
    Keys equal to ROUTE_PAD are padding: never stored, always ok=False.
    Duplicate keys keep global batch order (flat order == (source shard,
    local position) lexicographic == recv concatenation order).
    """
    fn = _sharded_call("insert", mesh, cfg, axis, shard_by, cap)
    return fn(hm_stacked, keys, vals)


# ---------------------------------------------------------------------------
# Fused whole-tick megakernel: probe -> delete -> insert in ONE shard_map
# ---------------------------------------------------------------------------

def routing_cap(keys, cfg: HashMemConfig, num_shards: int,
                shard_by: str = "mod", *, quantum: int = 8) -> int:
    """Pass 1 of the two-pass count+route scheme, host mirror: the max
    per-(src,dst) VALID-key count for a (Q,) batch laid out contiguously
    across ``num_shards`` devices (entries equal to ROUTE_PAD don't count —
    the fused route drops them).

    The result is rounded up to a multiple of ``quantum`` (bounds the set
    of compiled capacities to Q_local/quantum per batch shape) and clamped
    to [min(quantum, Q_local), Q_local].  The ORDER matters: the quantum
    floor applies first and the Q_local ceiling LAST, so a tiny batch
    (Q_local < quantum) caps at Q_local — a cap above Q_local would trace
    an all_to_all buffer larger than the (num_shards, Q_local) source
    slice.  Rounding is UP, so the capacity can never truncate; on a
    skewed tick it tracks the measured max instead of the worst-case
    Q_local the unfused path pads to.
    """
    k = np.asarray(keys, np.uint32)
    q = k.shape[0]
    assert q % num_shards == 0, (q, num_shards)
    q_local = q // num_shards
    valid = k != ROUTE_PAD
    mx = 0
    if valid.any():
        owner = owner_of_np(k, cfg, num_shards, shard_by)
        src = np.arange(q) // q_local
        pair = (src * num_shards + owner)[valid]
        mx = int(np.bincount(pair, minlength=num_shards * num_shards).max())
    cap = max(quantum, -(-mx // quantum) * quantum)
    cap = min(cap, q_local)                 # ceiling wins over the floor
    assert cap <= q_local, (cap, q_local)
    return cap


def _tick_shard_fn(cfg, num_shards, axis, shard_by, caps):
    cap_p, cap_d, cap_i = caps

    def shard_fn(hm_stacked_local, pq, dq, ik, iv):
        hm1 = jax.tree.map(lambda x: x[0], hm_stacked_local)
        pad = jnp.uint32(ROUTE_PAD)
        cp = cap_p or pq.shape[0]
        cd = cap_d or dq.shape[0]
        ci = cap_i or ik.shape[0]
        po, _ = owner_and_local_bucket(pq, cfg, num_shards, shard_by)
        do = owner_of(dq, cfg, num_shards, shard_by)
        io, _ = owner_and_local_bucket(ik, cfg, num_shards, shard_by)
        rt_p = _Route(pq, po, num_shards, cp, pad, drop_invalid=True)
        rt_d = _Route(dq, do, num_shards, cd, pad, drop_invalid=True)
        rt_i = _Route(ik, io, num_shards, ci, pad, drop_invalid=True)
        # pass 1 on-device: ONE small all_to_all of per-(src,dst) valid
        # counts for all three phases — row s of the result is what shard s
        # sent me, so counts_in[s, ph] bounds the dense prefix of recv row s
        counts = jnp.stack([rt_p.counts(), rt_d.counts(), rt_i.counts()],
                           axis=-1)                       # (D, 3)
        counts_in = jax.lax.all_to_all(counts, axis, 0, 0, tiled=False)
        # pass 2: routed payloads at the measured capacities
        # -- probe (pre-tick table) ----------------------------------------
        recv_p = jax.lax.all_to_all(rt_p.send, axis, 0, 0, tiled=False)
        rv, rf = _local_probe(hm1, recv_p.reshape(-1), cfg, num_shards,
                              shard_by)
        back_v = jax.lax.all_to_all(rv.reshape(num_shards, cp), axis, 0, 0,
                                    tiled=False)
        back_f = jax.lax.all_to_all(rf.reshape(num_shards, cp), axis, 0, 0,
                                    tiled=False)
        # -- delete ---------------------------------------------------------
        recv_d = jax.lax.all_to_all(rt_d.send, axis, 0, 0, tiled=False)
        flat_d = recv_d.reshape(-1)
        _, lb_d = owner_and_local_bucket(flat_d, cfg, num_shards, shard_by)
        hm2, dfound = hashmap.delete_with_buckets(hm1, flat_d, lb_d)
        back_df = jax.lax.all_to_all(dfound.reshape(num_shards, cd), axis,
                                     0, 0, tiled=False)
        # -- insert (post-delete table) -------------------------------------
        recv_k = jax.lax.all_to_all(rt_i.send, axis, 0, 0, tiled=False)
        recv_v = jax.lax.all_to_all(
            rt_i.send_aux(iv, num_shards, jnp.uint32(0)), axis, 0, 0,
            tiled=False)
        flat_k = recv_k.reshape(-1)
        # validity from the count exchange: slot j of recv row s is a real
        # key iff j < counts_in[s, 2] (the routed prefix is dense)
        valid = (jnp.arange(ci, dtype=I32)[None, :]
                 < counts_in[:, 2:3]).reshape(-1)
        _, lb_i = owner_and_local_bucket(flat_k, cfg, num_shards, shard_by)
        hm3, iok = hashmap.insert_with_buckets(hm2, flat_k,
                                               recv_v.reshape(-1), lb_i,
                                               valid=valid)
        back_ok = jax.lax.all_to_all(iok.reshape(num_shards, ci), axis, 0, 0,
                                     tiled=False)
        hm_out = jax.tree.map(lambda x: x[None], hm3)
        return (hm_out,
                rt_p.gather_back(back_v),
                rt_p.gather_back(back_f, mask_overflow=True),
                rt_d.gather_back(back_df, mask_overflow=True),
                rt_i.gather_back(back_ok, mask_overflow=True))
    return shard_fn, 5, 5


def tick_mesh(mesh, hm_stacked, probe_q, del_q, ins_k, ins_v,
              cfg: HashMemConfig, axis: str = "model",
              caps=None, shard_by: str = "mod"):
    """A whole coalesced serving tick in ONE shard_map call: the sharded
    PageStore pytree is carried functionally through probe -> delete ->
    insert on-device, so a tick costs one host<->mesh launch instead of
    three (the paper's one-activation-per-chain-step economics applied to
    the launch path).

    ``caps``: per-phase (probe, delete, insert) per-(src,dst) routing
    capacities from the two-pass scheme — compute each with
    ``routing_cap`` on the same batches; ``None`` (or a 0 entry) falls
    back to the worst-case Q_local padding.  Entries equal to ROUTE_PAD
    are padding in every phase: dropped from routing (they consume no
    capacity), never stored, results 0/False.

    Returns (hm_stacked', probe_vals, probe_found, del_found, ins_ok) with
    phase semantics identical to ``probe_sharded`` (against the pre-tick
    table) -> ``delete_sharded`` -> ``insert_mesh`` (against the
    post-delete table) issued back to back.
    """
    caps = tuple(caps) if caps is not None else (None, None, None)
    assert len(caps) == 3, caps
    fn = _sharded_call("tick", mesh, cfg, axis, shard_by, caps)
    return fn(hm_stacked, probe_q, del_q, ins_k, ins_v)


def probe_replicated(mesh, hm, queries, cfg: HashMemConfig, axis: str = "data"):
    """Throughput mode: HashMem replicated, queries sharded over `axis`
    (pure DP — the paper's multi-rank replication counterpoint)."""
    def shard_fn(hm_local, q_local):
        return hashmap.probe(hm_local, q_local, backend=cfg.backend)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )
    return fn(hm, queries)
