"""RLU: orchestration between probe requests and HashMem shards.

Single-device: the RLU resolves each probe key to its page chain (the
"command stream", hashmap.resolve_pages) and issues it to a compare backend.

Multi-device ("channel-level parallelism", paper §6 — future work there,
IMPLEMENTED here): buckets are partitioned across the mesh 'model' axis the
way the paper spreads pages "across different channels and ranks ... to
enable the parallel probing of pages".  One global hash h(key) defines

    owner shard  = h mod D
    local bucket = (h div D) mod num_buckets_local

Probes are routed to owners with ``all_to_all``, probed locally with the
configured kernel backend, and routed back — the TPU ICI plays the role of
the paper's memory-channel fan-out.

Every shard is a full HashMem over the unified PageStore (one interleaved
(P, S, 2) pool pytree per shard), so stacking shards for the mesh, the
synchronized-growth insert path and the local kernel probes all move ONE
pool leaf per shard instead of split key/value pairs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.core.hashing import EMPTY_KEY, HASH_FNS
from repro.core.probe import probe_pages
from repro.core.compat import shard_map

U32 = jnp.uint32
I32 = jnp.int32


def owner_and_local_bucket(keys, cfg: HashMemConfig, num_shards: int):
    h = HASH_FNS[cfg.hash_fn](keys.astype(U32), cfg.salt)
    owner = (h % U32(num_shards)).astype(I32)
    local = ((h // U32(num_shards)) % U32(cfg.num_buckets)).astype(I32)
    return owner, local


def build_sharded(cfg: HashMemConfig, keys, vals, num_shards: int):
    """Build per-shard HashMems; returns a stacked pytree with leading axis
    num_shards (shard i's arrays at index i), ready to shard over 'model'.

    cfg.num_buckets is the PER-SHARD bucket count.
    """
    owner, local = owner_and_local_bucket(keys, cfg, num_shards)
    shards = []
    for d in range(num_shards):
        m = owner == d
        # density: route shard-d keys to front; pad with EMPTY (never probed)
        idx = jnp.argsort(~m)  # shard-d keys first
        k = jnp.where(m[idx], keys[idx].astype(U32), EMPTY_KEY)
        v = jnp.where(m[idx], vals[idx].astype(U32), U32(0))
        b = jnp.where(m[idx], local[idx], 0)
        # EMPTY keys land in bucket 0 but as EMPTY they never match a probe;
        # they do consume slots, so size the scaled config accordingly.
        shards.append(hashmap.build_with_buckets(cfg, k, v, b))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)


def _local_bucket_fn(num_shards: int):
    """bucket_fn for hashmap.grow/insert on one shard: re-derive the local
    bucket from the global hash under the (possibly grown) shard config."""
    def fn(keys, cfg: HashMemConfig):
        h = HASH_FNS[cfg.hash_fn](keys.astype(U32), cfg.salt)
        return ((h // U32(num_shards)) % U32(cfg.num_buckets)).astype(I32)
    return fn


def insert_sharded(hm_stacked, keys, vals, cfg: HashMemConfig,
                   num_shards: int, max_grows: int = 4):
    """Host-level routed insert into the stacked shard pytree.

    Keys are routed to their owner shard (same global-hash split as
    build_sharded) and batch-inserted with the vectorized engine.  When any
    shard reports PR_ERROR and cfg.auto_grow is set, ALL shards grow by the
    same factor — the stacked pytree must stay shape-homogeneous to remain
    shardable over the mesh axis — and the failed elements retry.

    Returns (hm_stacked', ok (N,) bool, cfg').  cfg' differs from cfg after
    growth; pass it to subsequent probe_sharded/insert_sharded calls.
    """
    import numpy as np
    keys = jnp.asarray(keys).astype(U32)
    vals = jnp.asarray(vals).astype(U32)
    n = keys.shape[0]
    owner, _ = owner_and_local_bucket(keys, cfg, num_shards)  # owner is
    owner_np = np.asarray(owner)                              # grow-invariant
    bfn = _local_bucket_fn(num_shards)
    shards = [jax.tree.map(lambda x, d=d: x[d], hm_stacked)
              for d in range(num_shards)]

    ok = np.zeros((n,), bool)
    remaining = {d: np.nonzero(owner_np == d)[0] for d in range(num_shards)}
    grows = 0
    while True:
        any_fail = False
        for d in range(num_shards):
            idx = remaining[d]
            if idx.size == 0:
                continue
            kd, vd = keys[idx], vals[idx]
            hm_d, ok_d = hashmap.insert_with_buckets(
                shards[d], kd, vd, bfn(kd, shards[d].config))
            shards[d] = hm_d
            ok_np = np.asarray(ok_d)
            ok[idx[ok_np]] = True
            remaining[d] = idx[~ok_np]
            any_fail |= remaining[d].size > 0
        if not any_fail or not cfg.auto_grow or grows >= max_grows:
            break
        # synchronized growth keeps every shard the same shape
        shards = [hashmap.grow(s, bucket_fn=bfn) for s in shards]
        grows += 1

    hm_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
    return hm_stacked, jnp.asarray(ok), shards[0].config


def _local_probe(hm_local, queries, cfg: HashMemConfig, num_shards: int):
    _, local_bucket = owner_and_local_bucket(queries, cfg, num_shards)
    pages = hashmap.resolve_pages_by_bucket(hm_local, local_bucket)
    return probe_pages(hm_local, queries.astype(U32), pages, backend=cfg.backend)


def probe_sharded(mesh, hm_stacked, queries, cfg: HashMemConfig,
                  axis: str = "model", cap: Optional[int] = None):
    """Channel-parallel probe: queries (Q,) sharded over `axis`.

    cap = per-(src,dst) routing capacity; None -> Q_local (always sufficient).
    Returns (values (Q,), found (Q,)) with the same sharding as queries.
    """
    num_shards = mesh.shape[axis]

    def shard_fn(hm_stacked_local, q_local):
        hm_local = jax.tree.map(lambda x: x[0], hm_stacked_local)
        qn = q_local.shape[0]
        c = cap or qn
        owner, _ = owner_and_local_bucket(q_local, cfg, num_shards)
        order = jnp.argsort(owner)
        q_sorted = q_local[order].astype(U32)
        o_sorted = owner[order]
        # position within each owner group
        start = jnp.searchsorted(o_sorted, o_sorted, side="left")
        pos = jnp.arange(qn, dtype=I32) - start.astype(I32)
        overflow = pos >= c
        send = jnp.full((num_shards, c), EMPTY_KEY, dtype=U32)
        send = send.at[o_sorted, jnp.minimum(pos, c - 1)].set(
            jnp.where(overflow, EMPTY_KEY, q_sorted))
        # route to owners: recv[s] = what shard s sent to me
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0, tiled=False)
        rv, rf = _local_probe(hm_local, recv.reshape(-1), cfg, num_shards)
        # route results back
        back_v = jax.lax.all_to_all(rv.reshape(num_shards, c), axis, 0, 0, tiled=False)
        back_f = jax.lax.all_to_all(rf.reshape(num_shards, c), axis, 0, 0, tiled=False)
        v_sorted = back_v[o_sorted, jnp.minimum(pos, c - 1)]
        f_sorted = back_f[o_sorted, jnp.minimum(pos, c - 1)] & ~overflow
        inv = jnp.argsort(order)
        return v_sorted[inv], f_sorted[inv]

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )
    return fn(hm_stacked, queries)


def probe_replicated(mesh, hm, queries, cfg: HashMemConfig, axis: str = "data"):
    """Throughput mode: HashMem replicated, queries sharded over `axis`
    (pure DP — the paper's multi-rank replication counterpoint)."""
    def shard_fn(hm_local, q_local):
        return hashmap.probe(hm_local, q_local, backend=cfg.backend)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )
    return fn(hm, queries)
