from repro.data.pipeline import SyntheticLMData, make_batch_specs
from repro.data.kv_synth import kv_dataset, dictionary_words
