"""Key/value workload generators for the HashMem microbenchmark (paper §4.1.1)
and the dictionary-word bucket-distribution study (paper Fig. 4)."""
from __future__ import annotations

import numpy as np


def kv_dataset(num_pairs: int, seed: int = 0):
    """Unique uint32 keys + values (paper: 100M pairs, 4B key + 4B value)."""
    rng = np.random.default_rng(seed)
    # unique keys below the sentinel range
    keys = rng.choice(np.uint32(0xFFFFFFF0), size=num_pairs, replace=False) \
        if num_pairs <= 2**26 else _unique_keys_large(rng, num_pairs)
    vals = rng.integers(0, 2**32 - 1, size=num_pairs, dtype=np.uint64) \
        .astype(np.uint32)
    return keys.astype(np.uint32), vals


def _unique_keys_large(rng, n):
    # sampling without replacement at 100M scale: random 64-bit, hash to 32,
    # dedupe, top-up
    keys = np.unique((rng.integers(0, 0xFFFFFFF0, size=int(n * 1.2),
                                   dtype=np.uint64)).astype(np.uint32))
    while keys.size < n:
        extra = (rng.integers(0, 0xFFFFFFF0, size=n, dtype=np.uint64)
                 ).astype(np.uint32)
        keys = np.unique(np.concatenate([keys, extra]))
    rng.shuffle(keys)
    return keys[:n]


def probe_set(keys: np.ndarray, fraction: float, seed: int = 1):
    """Paper: 10% of keys probed, selected at random."""
    rng = np.random.default_rng(seed)
    n = int(len(keys) * fraction)
    idx = rng.choice(len(keys), size=n, replace=False)
    return keys[idx], idx


def churn_workload(n_ops: int, keyspace: int = 4096, insert_batch: int = 8,
                   delete_batch: int = 4, probe_batch: int = 16,
                   p_insert: float = 0.5, p_delete: float = 0.25,
                   seed: int = 0):
    """Mixed online-mutation op stream for the mutation engine.

    Yields ``(op, keys, vals)`` tuples with op in {"insert", "delete",
    "probe"}; keys are drawn Zipf-skewed from a bounded keyspace so the
    stream produces duplicate keys, tombstone-then-reinsert patterns and
    hot buckets — the access shape a live serving table sees, as opposed to
    the paper's populate-once microbenchmark (kv_dataset above).
    """
    rng = np.random.default_rng(seed)
    pool = rng.choice(np.uint32(0xFFFFFFF0), size=keyspace,
                      replace=False).astype(np.uint32)
    # Zipf-ish ranks: hot head, long tail
    w = 1.0 / np.arange(1, keyspace + 1) ** 0.8
    w /= w.sum()
    for _ in range(n_ops):
        r = rng.random()
        if r < p_insert:
            k = rng.choice(pool, size=insert_batch, p=w)
            v = rng.integers(1, 2**31, size=insert_batch,
                             dtype=np.int64).astype(np.uint32)
            yield "insert", k, v
        elif r < p_insert + p_delete:
            yield "delete", rng.choice(pool, size=delete_batch, p=w), None
        else:
            yield "probe", rng.choice(pool, size=probe_batch, p=w), None


def zipfian_weights(n: int, theta: float = 0.99) -> np.ndarray:
    """YCSB Zipfian popularity weights over ranks 1..n (hot head, long tail).

    ``theta`` is the YCSB skew constant (0.99 is the YCSB default; 0 is
    uniform).  Returned weights are normalized to sum to 1.
    """
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** theta
    return w / w.sum()


# YCSB core workload op mixes (Cooper et al., SoCC'10).  "rmw" is
# read-modify-write; "scan" reads a short run of consecutive keys.  The
# standard key distribution per workload is noted for the loadgen defaults.
_YCSB_MIXES = {
    "A": {"read": 0.5, "update": 0.5},
    "B": {"read": 0.95, "update": 0.05},
    "C": {"read": 1.0},
    "D": {"read": 0.95, "insert": 0.05},
    "E": {"scan": 0.95, "insert": 0.05},
    "F": {"read": 0.5, "rmw": 0.5},
}
_YCSB_DISTS = {"A": "zipfian", "B": "zipfian", "C": "zipfian",
               "D": "latest", "E": "zipfian", "F": "zipfian"}


def ycsb_mix(workload: str) -> dict:
    """Op mix for YCSB core workload A-F as {op_kind: probability}."""
    wl = workload.upper()
    if wl not in _YCSB_MIXES:
        raise KeyError(f"unknown YCSB workload {workload!r}; "
                       f"available: {sorted(_YCSB_MIXES)}")
    return dict(_YCSB_MIXES[wl])


def ycsb_default_dist(workload: str) -> str:
    """The standard key distribution for a YCSB core workload."""
    return _YCSB_DISTS[workload.upper()]


def zipfian_workload(n_ops: int, keyspace: int = 4096, theta: float = 0.99,
                     insert_batch: int = 8, delete_batch: int = 4,
                     probe_batch: int = 16, mix=None, workload: str = None,
                     seed: int = 0):
    """Zipfian-skewed mixed op stream in the same ``(op, keys, vals)`` shape
    as :func:`churn_workload` — consumable by both the serving loadgen's
    preload path and the differential harness's skew schedules.

    ``mix`` maps {"insert", "delete", "probe"} to probabilities (defaults to
    churn_workload's 0.5/0.25/0.25).  Alternatively pass ``workload`` (YCSB
    A-F): reads/scans map to "probe", updates/inserts/rmw to "insert", and a
    small delete fraction is mixed in so tombstone paths stay exercised.
    """
    rng = np.random.default_rng(seed)
    if workload is not None:
        ym = ycsb_mix(workload)
        p_probe = ym.get("read", 0.0) + ym.get("scan", 0.0)
        p_insert = ym.get("update", 0.0) + ym.get("insert", 0.0) \
            + ym.get("rmw", 0.0)
        # fold a 5% delete share in proportionally so tombstones appear
        mix = {"probe": 0.95 * p_probe, "insert": 0.95 * p_insert,
               "delete": 0.05}
    mix = mix or {"insert": 0.5, "delete": 0.25, "probe": 0.25}
    total = sum(mix.values())
    p_ins, p_del = mix.get("insert", 0) / total, mix.get("delete", 0) / total
    pool = rng.choice(np.uint32(0xFFFFFFF0), size=keyspace,
                      replace=False).astype(np.uint32)
    w = zipfian_weights(keyspace, theta)
    for _ in range(n_ops):
        r = rng.random()
        if r < p_ins:
            k = rng.choice(pool, size=insert_batch, p=w)
            v = rng.integers(1, 2**31, size=insert_batch,
                             dtype=np.int64).astype(np.uint32)
            yield "insert", k, v
        elif r < p_ins + p_del:
            yield "delete", rng.choice(pool, size=delete_batch, p=w), None
        else:
            yield "probe", rng.choice(pool, size=probe_batch, p=w), None


def dictionary_words(n: int = 350_000, seed: int = 3) -> np.ndarray:
    """Synthetic 'dictionary': Zipf-weighted letter n-grams dictionary-encoded
    to uint32 (paper Fig. 4 maps the first 350k words of a dictionary).
    Word keys are the dictionary-encoded numeric ids the paper prescribes for
    string data (§4.1.1)."""
    rng = np.random.default_rng(seed)
    # mimic word-length distribution 3..14, characters Zipf over 26 letters
    lengths = rng.integers(3, 15, size=n)
    p = 1.0 / np.arange(1, 27) ** 1.07
    p /= p.sum()
    out = np.zeros(n, np.uint32)
    seen = set()
    for i in range(n):
        while True:
            chars = rng.choice(26, size=lengths[i], p=p)
            h = 2166136261
            for c in chars:
                h = ((h ^ (int(c) + 97)) * 16777619) & 0xFFFFFFFF
            if h not in seen and h < 0xFFFFFFF0:
                seen.add(h)
                out[i] = h
                break
    return out
