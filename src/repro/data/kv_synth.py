"""Key/value workload generators for the HashMem microbenchmark (paper §4.1.1)
and the dictionary-word bucket-distribution study (paper Fig. 4)."""
from __future__ import annotations

import numpy as np


def kv_dataset(num_pairs: int, seed: int = 0):
    """Unique uint32 keys + values (paper: 100M pairs, 4B key + 4B value)."""
    rng = np.random.default_rng(seed)
    # unique keys below the sentinel range
    keys = rng.choice(np.uint32(0xFFFFFFF0), size=num_pairs, replace=False) \
        if num_pairs <= 2**26 else _unique_keys_large(rng, num_pairs)
    vals = rng.integers(0, 2**32 - 1, size=num_pairs, dtype=np.uint64) \
        .astype(np.uint32)
    return keys.astype(np.uint32), vals


def _unique_keys_large(rng, n):
    # sampling without replacement at 100M scale: random 64-bit, hash to 32,
    # dedupe, top-up
    keys = np.unique((rng.integers(0, 0xFFFFFFF0, size=int(n * 1.2),
                                   dtype=np.uint64)).astype(np.uint32))
    while keys.size < n:
        extra = (rng.integers(0, 0xFFFFFFF0, size=n, dtype=np.uint64)
                 ).astype(np.uint32)
        keys = np.unique(np.concatenate([keys, extra]))
    rng.shuffle(keys)
    return keys[:n]


def probe_set(keys: np.ndarray, fraction: float, seed: int = 1):
    """Paper: 10% of keys probed, selected at random."""
    rng = np.random.default_rng(seed)
    n = int(len(keys) * fraction)
    idx = rng.choice(len(keys), size=n, replace=False)
    return keys[idx], idx


def dictionary_words(n: int = 350_000, seed: int = 3) -> np.ndarray:
    """Synthetic 'dictionary': Zipf-weighted letter n-grams dictionary-encoded
    to uint32 (paper Fig. 4 maps the first 350k words of a dictionary).
    Word keys are the dictionary-encoded numeric ids the paper prescribes for
    string data (§4.1.1)."""
    rng = np.random.default_rng(seed)
    # mimic word-length distribution 3..14, characters Zipf over 26 letters
    lengths = rng.integers(3, 15, size=n)
    p = 1.0 / np.arange(1, 27) ** 1.07
    p /= p.sum()
    out = np.zeros(n, np.uint32)
    seen = set()
    for i in range(n):
        while True:
            chars = rng.choice(26, size=lengths[i], p=p)
            h = 2166136261
            for c in chars:
                h = ((h ^ (int(c) + 97)) * 16777619) & 0xFFFFFFFF
            if h not in seen and h < 0xFFFFFFF0:
                seen.add(h)
                out[i] = h
                break
    return out
