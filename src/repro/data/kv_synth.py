"""Key/value workload generators for the HashMem microbenchmark (paper §4.1.1)
and the dictionary-word bucket-distribution study (paper Fig. 4)."""
from __future__ import annotations

import numpy as np


def kv_dataset(num_pairs: int, seed: int = 0):
    """Unique uint32 keys + values (paper: 100M pairs, 4B key + 4B value)."""
    rng = np.random.default_rng(seed)
    # unique keys below the sentinel range
    keys = rng.choice(np.uint32(0xFFFFFFF0), size=num_pairs, replace=False) \
        if num_pairs <= 2**26 else _unique_keys_large(rng, num_pairs)
    vals = rng.integers(0, 2**32 - 1, size=num_pairs, dtype=np.uint64) \
        .astype(np.uint32)
    return keys.astype(np.uint32), vals


def _unique_keys_large(rng, n):
    # sampling without replacement at 100M scale: random 64-bit, hash to 32,
    # dedupe, top-up
    keys = np.unique((rng.integers(0, 0xFFFFFFF0, size=int(n * 1.2),
                                   dtype=np.uint64)).astype(np.uint32))
    while keys.size < n:
        extra = (rng.integers(0, 0xFFFFFFF0, size=n, dtype=np.uint64)
                 ).astype(np.uint32)
        keys = np.unique(np.concatenate([keys, extra]))
    rng.shuffle(keys)
    return keys[:n]


def probe_set(keys: np.ndarray, fraction: float, seed: int = 1):
    """Paper: 10% of keys probed, selected at random."""
    rng = np.random.default_rng(seed)
    n = int(len(keys) * fraction)
    idx = rng.choice(len(keys), size=n, replace=False)
    return keys[idx], idx


def churn_workload(n_ops: int, keyspace: int = 4096, insert_batch: int = 8,
                   delete_batch: int = 4, probe_batch: int = 16,
                   p_insert: float = 0.5, p_delete: float = 0.25,
                   seed: int = 0):
    """Mixed online-mutation op stream for the mutation engine.

    Yields ``(op, keys, vals)`` tuples with op in {"insert", "delete",
    "probe"}; keys are drawn Zipf-skewed from a bounded keyspace so the
    stream produces duplicate keys, tombstone-then-reinsert patterns and
    hot buckets — the access shape a live serving table sees, as opposed to
    the paper's populate-once microbenchmark (kv_dataset above).
    """
    rng = np.random.default_rng(seed)
    pool = rng.choice(np.uint32(0xFFFFFFF0), size=keyspace,
                      replace=False).astype(np.uint32)
    # Zipf-ish ranks: hot head, long tail
    w = 1.0 / np.arange(1, keyspace + 1) ** 0.8
    w /= w.sum()
    for _ in range(n_ops):
        r = rng.random()
        if r < p_insert:
            k = rng.choice(pool, size=insert_batch, p=w)
            v = rng.integers(1, 2**31, size=insert_batch,
                             dtype=np.int64).astype(np.uint32)
            yield "insert", k, v
        elif r < p_insert + p_delete:
            yield "delete", rng.choice(pool, size=delete_batch, p=w), None
        else:
            yield "probe", rng.choice(pool, size=probe_batch, p=w), None


def dictionary_words(n: int = 350_000, seed: int = 3) -> np.ndarray:
    """Synthetic 'dictionary': Zipf-weighted letter n-grams dictionary-encoded
    to uint32 (paper Fig. 4 maps the first 350k words of a dictionary).
    Word keys are the dictionary-encoded numeric ids the paper prescribes for
    string data (§4.1.1)."""
    rng = np.random.default_rng(seed)
    # mimic word-length distribution 3..14, characters Zipf over 26 letters
    lengths = rng.integers(3, 15, size=n)
    p = 1.0 / np.arange(1, 27) ** 1.07
    p /= p.sum()
    out = np.zeros(n, np.uint32)
    seen = set()
    for i in range(n):
        while True:
            chars = rng.choice(26, size=lengths[i], p=p)
            h = 2166136261
            for c in chars:
                h = ((h ^ (int(c) + 97)) * 16777619) & 0xFFFFFFFF
            if h not in seen and h < 0xFFFFFFF0:
                seen.add(h)
                out[i] = h
                break
    return out
