"""Deterministic sharded synthetic data pipeline.

Production-shaped: each host generates ONLY its shard of the global batch
(indexed by (step, shard) so restarts are reproducible and elastic re-shards
keep the token stream identical), with background prefetch of the next batch.

The token stream is a mixture of Zipf-distributed unigrams and a repeated
n-gram "grammar" so small models show a real, declining loss curve (pure
uniform noise would pin the loss at log V).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLMData:
    def __init__(self, cfg, shape, *, seed: int = 0, shard_index: int = 0,
                 num_shards: int = 1, prefetch: int = 2):
        assert shape.global_batch % num_shards == 0
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.local_batch = shape.global_batch // num_shards
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._step = 0
        # Zipf-ish unigram distribution over the vocab
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    # --- deterministic batch materialization -----------------------------
    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard_index)
        B, S = self.local_batch, self.shape.seq_len
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            dec_len = min(512, S)
            frames = rng.standard_normal((B, S, cfg.d_model), np.float32)
            toks = rng.choice(cfg.vocab_size, size=(B, dec_len + 1), p=self._p)
            return {"frames": frames.astype(np.float32),
                    "dec_tokens": toks[:, :-1].astype(np.int32),
                    "labels": toks[:, 1:].astype(np.int32)}
        toks = self._grammar_tokens(rng, B, S + 1)
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if cfg.family == "vlm":
            P_ = cfg.num_prefix_embeds
            batch["patch_embeds"] = rng.standard_normal(
                (B, P_, cfg.d_model)).astype(np.float32)
            batch["tokens"] = batch["tokens"][:, :S - P_]
            lab = np.full((B, S), -100, np.int64)
            lab[:, P_:] = toks[:, P_ + 1:]
            batch["labels"] = lab.astype(np.int32)
        return batch

    def _grammar_tokens(self, rng, B, n):
        cfg = self.cfg
        base = rng.choice(cfg.vocab_size, size=(B, n), p=self._p)
        mask = rng.random((B, n - 1)) < 0.6
        # inject learnable structure: token t+1 = (3 t + 7) % V on 60% of
        # steps, applied sequentially so the rule holds on the FINAL stream
        for t in range(1, n):
            det = (3 * base[:, t - 1] + 7) % cfg.vocab_size
            base[:, t] = np.where(mask[:, t - 1], det, base[:, t])
        return base

    # --- prefetch iterator -------------------------------------------------
    def _worker(self, start_step: int):
        step = start_step
        while not self._stop.is_set():
            try:
                self._q.put(self.batch_at(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self.iterator(0)

    def iterator(self, start_step: int) -> Iterator[dict]:
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._worker, args=(start_step,), daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self.close()

    def close(self):
        self._stop.set()


def make_batch_specs(mesh, batch: dict):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import batch_spec
    return {k: NamedSharding(mesh, P(batch_spec(mesh, v.shape[0]),
                                     *([None] * (v.ndim - 1))))
            for k, v in batch.items()}
