"""Gradient compression for the cross-pod all-reduce.

Modes:
  bf16    — cast gradients to bf16 before the reduce (2x wire bytes saved);
            standard at pod scale.
  int8_ef — per-tensor symmetric int8 quantization with ERROR FEEDBACK: the
            quantization residual is carried to the next step (Seide et al.,
            1-bit SGD lineage), so compression error does not accumulate.

compress_tree is stateless (bf16); Int8ErrorFeedback carries the residual
state and is exercised in tests for convergence on a quadratic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_tree(grads, mode: str):
    if mode == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)
    if mode == "int8":
        return jax.tree.map(_int8_roundtrip, grads)
    raise ValueError(mode)


def _int8_roundtrip(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return (q.astype(g.dtype) * scale).astype(g.dtype)


class Int8ErrorFeedback:
    """g_t' = Q(g_t + e_{t-1}); e_t = (g_t + e_{t-1}) - g_t'."""

    def init(self, grads):
        return jax.tree.map(jnp.zeros_like, grads)

    def apply(self, grads, err):
        corrected = jax.tree.map(lambda g, e: g + e, grads, err)
        quant = jax.tree.map(_int8_roundtrip, corrected)
        new_err = jax.tree.map(lambda c, q: c - q, corrected, quant)
        return quant, new_err
