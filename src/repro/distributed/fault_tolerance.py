"""Fault tolerance: failure injection, restart policy, straggler mitigation.

On a real multi-pod job the failure signal is a lost heartbeat / XLA launch
error; here failures are injected deterministically so the restart path is
exercised end-to-end in tests (launch/train.py --inject-failure-at).

Straggler mitigation: per-step deadline tracking.  Steps slower than
``factor``x the running median are flagged; the driver's response at scale is
to reissue the step on the backup ('pod') replica — here the reissue is
simulated (the step function is deterministic, so the backup result equals
the original) and counted, which tests the detection logic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List


class InjectedFailure(RuntimeError):
    """Simulated node failure."""


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    warmup: int = 5
    times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)
    backup_runs: int = 0

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if the step was flagged as a straggler."""
        self.times.append(seconds)
        if len(self.times) <= self.warmup:
            return False
        med = sorted(self.times[:-1])[len(self.times[:-1]) // 2]
        if seconds > self.factor * max(med, 1e-9):
            self.flagged.append(step)
            self.backup_runs += 1          # backup replica reissues the step
            return True
        return False


@dataclass
class RestartPolicy:
    max_restarts: int = 3
    restarts: int = 0
    backoff_s: float = 0.0

    def on_failure(self, err: Exception) -> bool:
        """True -> restart; False -> give up."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return False
        if self.backoff_s:
            time.sleep(self.backoff_s)
        return True
