"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) for the production
mesh: single-pod (16,16) ("data","model") and multi-pod (2,16,16)
("pod","data","model").

Rules map logical axis names from model init (layers.Axes) to mesh axes.
A rule is dropped (replicated) per-array-dimension when the dimension size
does not divide the mesh-axis product — e.g. whisper-tiny's 6 heads on a
16-way 'model' axis, or GQA kv_heads=8 (< 16): Megatron-style replication.
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import Axes

# logical axis -> mesh axes (tuple = joint sharding)
RULES = {
    "batch": ("pod", "data"),
    "embed": ("data",),          # FSDP weight shard
    "mlp": ("model",),           # TP
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "expert": ("data",),         # EP
    # paged-KV grouped layout: pages jointly sharded over the whole mesh
    # (batch groups x channels; paper §6 channel parallelism)
    "kv_pages": ("pod", "data", "model"),
    "act_seq": ("model",),       # sequence-parallel residual stream
    # replicated:
    "layers": (), "state": (), "conv": (), "dt_rank": (), "head_dim": (),
    "seq": (), "gates": (),
}


def mesh_axes_for(mesh: Mesh, logical: str):
    axes = tuple(a for a in RULES.get(logical, ()) if a in mesh.axis_names)
    return axes


def spec_for(mesh: Mesh, axes: Axes, shape) -> P:
    """PartitionSpec for one array given its logical axes + shape, with
    divisibility fallback to replication."""
    parts = []
    used = set()
    for name, dim in zip(tuple(axes), shape):
        maxes = tuple(a for a in mesh_axes_for(mesh, name) if a not in used)
        size = int(np.prod([mesh.shape[a] for a in maxes])) if maxes else 1
        if maxes and dim % size == 0:
            parts.append(maxes if len(maxes) > 1 else maxes[0])
            used.update(maxes)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_specs(cfg, mesh: Mesh):
    """PartitionSpec tree matching init_params(cfg)."""
    from repro.models import model
    shapes = jax.eval_shape(lambda k: model.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    axes = model.param_axes(cfg)
    return jax.tree.map(
        lambda a, s: spec_for(mesh, a, s.shape),
        axes, shapes, is_leaf=lambda x: isinstance(x, Axes))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, global_batch: int):
    """Dim-entry for the batch dimension (tuple of mesh axes, or None)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch % size == 0:
        return axes
    # long_500k batch=1: replicate batch, parallelism comes from kv pages
    return None


def batch_specs(cfg, mesh: Mesh, batch_tree):
    """Input sharding specs for a train/prefill batch dict."""
    bs = {k: None for k in batch_tree}
    out = {}
    for k, v in batch_tree.items():
        spec = [batch_spec(mesh, v.shape[0])]
        spec += [None] * (len(v.shape) - 1)
        out[k] = P(*spec)
    return out


# ---------------------------------------------------------------------------
# Stacked-HashMem placement (serving-engine mesh shards; core/rlu.py)
# ---------------------------------------------------------------------------

def stacked_hashmem_specs(hm_stacked, axis: str = "model"):
    """PartitionSpec tree for a stacked shard pytree (leading dim =
    num_shards): every leaf shards its leading axis over ``axis``, which
    places exactly one HashMem shard per device along the mesh axis."""
    return jax.tree.map(lambda _: P(axis), hm_stacked)


def shard_stacked_hashmem(mesh: Mesh, hm_stacked, axis: str = "model"):
    """Place a stacked shard pytree onto the mesh (one shard per device on
    ``axis``).  Done once at table build/growth time so the per-tick RLU
    calls (probe_sharded / delete_sharded / insert_mesh) start from
    device-resident shards instead of resharding host arrays every call."""
    return jax.device_put(
        hm_stacked, named(mesh, stacked_hashmem_specs(hm_stacked, axis)))


class ShardCtx:
    """Activation sharding constraints threaded through the model.

    seq_shard=True applies Megatron-style sequence parallelism to the
    residual stream between layer units (keeps the lax.scan carry — the
    dominant live activation — at 1/|model| per chip).
    """

    def __init__(self, mesh: Mesh, seq_shard: bool = False):
        self.mesh = mesh
        self.seq_shard = seq_shard
        self._baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def residual(self, x):
        """x (B,S,d) constraint at unit boundaries."""
        if not self.seq_shard:
            return x
        B, S, _ = x.shape
        bspec = self._baxes if B % int(np.prod(
            [self.mesh.shape[a] for a in self._baxes])) == 0 else None
        sspec = "model" if S % self.mesh.shape["model"] == 0 else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(bspec, sspec)))
