"""pjit step factories: train_step and serve_step with full sharding specs.

``build_train_step``/``build_serve_step`` return (jitted_fn, shardings) so
both the real drivers (launch/train.py, launch/serve.py) and the dry-run
(launch/dryrun.py — .lower().compile() on ShapeDtypeStructs) use the exact
same compiled artifact.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model
from repro.models.layers import Axes
from repro.optim import adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def build_train_step(cfg, oc, mesh, *, seq_shard: bool = True,
                     grad_compression: str = "none"):
    pspec = shd.param_specs(cfg, mesh)
    pshard = shd.named(mesh, pspec)
    oshard = {"m": pshard, "v": pshard,
              "step": NamedSharding(mesh, P())}
    ctx = shd.ShardCtx(mesh, seq_shard=seq_shard)

    def train_step(params, opt_state, batch):
        def lf(p):
            return model.loss_fn(p, cfg, batch, shard_ctx=ctx)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if grad_compression != "none":
            from repro.distributed.compression import compress_tree
            grads = compress_tree(grads, grad_compression)
        new_params, new_opt, stats = adamw_update(params, grads, opt_state, oc)
        return new_params, new_opt, {"loss": loss, **metrics, **stats}

    def batch_shardings(batch_tree):
        return {k: NamedSharding(mesh, P(shd.batch_spec(mesh, v.shape[0]),
                                         *([None] * (v.ndim - 1))))
                for k, v in batch_tree.items()}

    def jitted(batch_tree):
        return jax.jit(
            train_step,
            in_shardings=(pshard, oshard, batch_shardings(batch_tree)),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )

    return train_step, jitted, pshard, oshard


def init_train_state(cfg, oc, mesh, key):
    """Sharded param/opt-state init (jit'd so arrays materialize sharded)."""
    pspec = shd.param_specs(cfg, mesh)
    pshard = shd.named(mesh, pspec)
    oshard = {"m": pshard, "v": pshard, "step": NamedSharding(mesh, P())}

    @partial(jax.jit, out_shardings=(pshard, oshard))
    def init(key):
        params = model.init_params(cfg, key)
        return params, init_opt_state(params, oc)

    return init(key)


# ---------------------------------------------------------------------------
# Serve (decode)
# ---------------------------------------------------------------------------

_STATE_AXES = {
    # name, ndim (without the stacked layer dim) -> logical axes
    ("k_pool", 4): ("kv_pages", "seq", "kv_heads", "head_dim"),
    ("v_pool", 4): ("kv_pages", "seq", "kv_heads", "head_dim"),
    ("conv", 3): ("batch", "conv", "mlp"),
    ("ssm", 3): ("batch", "mlp", "state"),
    ("C", 4): ("batch", "heads", "head_dim", "head_dim"),
    ("n", 3): ("batch", "heads", "head_dim"),
    ("m", 2): ("batch", "heads"),
    ("c", 3): ("batch", "heads", "head_dim"),
    ("n", 3): ("batch", "heads", "head_dim"),
    ("h", 3): ("batch", "heads", "head_dim"),
    ("m", 3): ("batch", "heads", "head_dim"),
    ("ek", 4): ("batch", "seq", "kv_heads", "head_dim"),
    ("ev", 4): ("batch", "seq", "kv_heads", "head_dim"),
}


def decode_state_specs(states, mesh):
    def spec(path, x):
        name = None
        for p_ in reversed(path):
            if hasattr(p_, "key"):
                name = p_.key
                break
        axes = _STATE_AXES.get((name, x.ndim - 1))
        if axes is None:
            return P()
        return shd.spec_for(mesh, Axes(("layers",) + axes), x.shape)

    flat, td = jax.tree_util.tree_flatten_with_path(states)
    return jax.tree_util.tree_unflatten(td, [spec(p_, x) for p_, x in flat])


def build_serve_step(cfg, serve_cfg, mesh, *, channel_axis: Optional[str] = "model"):
    del channel_axis  # topology derived from mesh (grouped layout)
    B = serve_cfg.shape.global_batch
    ctx = model.make_decode_ctx(cfg, serve_cfg, B, mesh=mesh)
    pspec = shd.param_specs(cfg, mesh)
    pshard = shd.named(mesh, pspec)
    bsp = shd.batch_spec(mesh, B)

    def serve_step(params, states, tokens, pos, block_table):
        logits, new_states = model.decode_step(
            params, cfg, states, tokens, pos, block_table, ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_states

    def jitted(state_tree):
        sspec = decode_state_specs(state_tree, mesh)
        sshard = shd.named(mesh, sspec)
        return jax.jit(
            serve_step,
            in_shardings=(pshard, sshard,
                          NamedSharding(mesh, P(bsp, None)),
                          NamedSharding(mesh, P(bsp)),
                          NamedSharding(mesh, P(bsp, None))),
            out_shardings=(NamedSharding(mesh, P(bsp)), None, sshard),
            donate_argnums=(1,),
        )

    return serve_step, jitted, ctx, pshard
