"""jit'd wrappers for the HashMem probe kernels.

All probe entry points take the unified PageStore's interleaved (P, S, 2)
pool — one page fetch per chain step serves both the key compare and the
value readout.  Page schedules may carry interior -1 holes (fingerprint-
filtered pages); the Pallas wrappers derive a forward-filled fetch index so
those steps cost no row activation.  ``interpret`` defaults to True off-TPU
(this container validates the kernel bodies in interpret mode; on a real
v5e the same calls lower to Mosaic).

These kernels never see the bucket directory: extendible-mode probes
resolve their page schedule through the same bucket_head gather as rebuild
mode (core/probe.py module docstring), so the kernel interface — (pool,
queries, pages) — is identical under both resize modes and across splits.
"""
from __future__ import annotations

import jax

from repro.core import layout
from repro.kernels.probe_area import probe_pages_area
from repro.kernels.probe_bitserial import probe_pages_bitserial
from repro.kernels.probe_perf import probe_pages_perf
from repro.kernels import ref

__all__ = [
    "probe_perf", "probe_area", "probe_bitserial", "probe_ref",
    "bitplane_update", "bitplane_rebuild",
]

probe_perf = jax.jit(probe_pages_perf)
probe_area = jax.jit(probe_pages_area)
probe_bitserial = jax.jit(probe_pages_bitserial, static_argnames=("key_bits",))
probe_ref = jax.jit(ref.probe_pages_ref)
probe_bitplanes_ref = jax.jit(ref.probe_bitplanes_ref, static_argnames=("key_bits",))

# bit-plane maintenance for the mutation engine: batched incremental update
# (insert/delete write sets) and the full from-scratch rebuild (grow/compact)
bitplane_update = jax.jit(layout.update_bitplanes_batch,
                          static_argnames=("key_bits",))
bitplane_rebuild = jax.jit(layout.pack_bitplanes, static_argnames=("key_bits",))
