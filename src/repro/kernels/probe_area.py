"""Area-optimized HashMem probe kernel (paper §2.1).

Paper mechanism: ONE comparison unit per subarray walks the activated row
buffer *element-serial, bit-parallel* — one key/value pair per step, matched
keys latched into the output register.

TPU adaptation (DESIGN.md §2): a TPU has no efficient scalar element walk
over VMEM; the closest faithful analogue is *strip-serial*: a fori_loop
steps through the row one 128-lane strip at a time, performing a single
compare per step and latching the first match — serial at strip granularity
(the "one comparator" is one VPU issue slot per step), versus probe_perf
which consumes the whole row at once.  The activated row is the interleaved
(slots, 2) key/value segment of the unified PageStore — ONE BlockSpec fetch
per chain step; each strip compares the key lane and latches the matching
value lane of the SAME row.  This preserves the paper's area/perf contrast:
same single-activation I/O, serialized compare schedule.

Same grid/O contract as probe_perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
LINE = 128
STRIP = 128


def _make_kernel(strip: int):
    def _kernel(pages_ref, fetch_ref, queries_ref, pool_ref, out_ref):
        del fetch_ref   # consumed by the BlockSpec index maps only
        c = pl.program_id(1)
        q = pl.program_id(0)

        @pl.when(c == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        page = pages_ref[q, c]
        query = queries_ref[q]
        valid = page >= 0
        kv = pool_ref[...]                                   # (1, S, 2): one activation
        keys_row = kv[0, :, 0]                               # (S,) uint32
        vals_row = kv[0, :, 1]
        S = keys_row.shape[0]
        n_strips = S // strip

        def body(i, carry):
            found, val, slot = carry
            krow = jax.lax.dynamic_slice_in_dim(keys_row, i * strip, strip)
            vrow = jax.lax.dynamic_slice_in_dim(vals_row, i * strip, strip)
            match = (krow == query) & valid
            any_m = jnp.any(match)
            iota = jax.lax.broadcasted_iota(jnp.int32, (strip,), 0)
            s_local = jnp.min(jnp.where(match, iota, jnp.int32(2**30)))
            v_local = jnp.max(jnp.where((iota == s_local) & match, vrow, U32(0)))
            take = any_m & jnp.logical_not(found)               # element-serial latch
            return (found | any_m,
                    jnp.where(take, v_local, val),
                    jnp.where(take, i * strip + s_local, slot))

        found, val, slot = jax.lax.fori_loop(
            0, n_strips, body, (jnp.bool_(False), U32(0), jnp.int32(0)))

        already = out_ref[0, 1] > U32(0)

        @pl.when(found & jnp.logical_not(already))
        def _write():
            out_ref[0, 0] = val
            out_ref[0, 1] = U32(1)
            out_ref[0, 2] = page.astype(U32)
            out_ref[0, 3] = slot.astype(U32)

    return _kernel


def probe_pages_area(pool, queries, pages, *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qn, C = pages.shape
    P, S, _ = pool.shape
    # full lane strips on real shapes; small test pages fall back to one strip
    strip = min(STRIP, S)
    assert S % strip == 0, "slots must be a multiple of the strip width"

    from repro.kernels.ref import fill_fetch_pages
    pages = pages.astype(jnp.int32)
    fetch = fill_fetch_pages(pages)   # filtered steps re-open the resident row

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(qn, C),
        in_specs=[
            pl.BlockSpec((1, S, 2),
                         lambda q, c, pages, fetch, queries: (fetch[q, c], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, LINE),
                               lambda q, c, pages, fetch, queries: (q, 0)),
    )
    out = pl.pallas_call(
        _make_kernel(strip),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qn, LINE), U32),
        interpret=interpret,
    )(pages, fetch, queries.astype(U32), pool)
    return out[:, 0], out[:, 1] > 0
