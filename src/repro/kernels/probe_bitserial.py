"""Bit-serial element-parallel HashMem probe kernel — the faithful §2.2 form.

Paper mechanism (performance-optimized version): keys are stored
column-oriented so "each row contains a single-bit slice from thousands of
values"; comparison proceeds one bit-plane per step — b steps for b-bit keys
— with ALL keys compared in parallel at every step.

TPU adaptation (DESIGN.md §2): bit-planes are packed 32-slots-per-uint32-word
(layout.pack_bitplanes); the per-bit step is a single vector XOR+OR over the
word lanes, so one grid step performs `key_bits` vector ops regardless of the
number of slots — exactly the paper's b-cycle CAM scan.  The value readout
comes from the unified PageStore's interleaved page row, but the BlockSpec
selects ONLY its value lane ((1, S, 1) block at lane index 1) — the
bit-serial layout keeps keys column-oriented, so the plane row IS the key
activation and fetching the pool's key lane too would double the per-step
row traffic for bytes the kernel never reads.  On TPU
this wins over probe_perf only for sub-32-bit keys (b = 4/8/16, the paper's
column widths); at b=32 the bit-parallel compare of probe_perf is strictly
better.  The benchmark harness quantifies that crossover (EXPERIMENTS.md
§Perf).

I/O: planes (P, b, W=S//32) u32 bit-planes, pool (P, S, 2) u32 interleaved
pages, queries (Q,) u32, pages (Q, C) i32.  Output cache line as probe_perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
LINE = 128


def _make_kernel(key_bits: int):
    def _kernel(pages_ref, fetch_ref, queries_ref, planes_ref, pool_ref,
                out_ref):
        del fetch_ref   # consumed by the BlockSpec index maps only
        c = pl.program_id(1)
        q = pl.program_id(0)

        @pl.when(c == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        page = pages_ref[q, c]
        query = queries_ref[q].astype(U32)
        valid = page >= 0
        W = planes_ref.shape[2]
        S = W * 32

        # --- the bit-serial scan: key_bits steps, all slots in parallel ---
        mismatch = jnp.zeros((1, W), U32)
        for j in range(key_bits):                            # static unroll: b steps
            qbit = (query >> U32(j)) & U32(1)
            qword = jnp.where(qbit > 0, U32(0xFFFFFFFF), U32(0))
            plane = planes_ref[0, j, :].reshape(1, W)
            mismatch = mismatch | (plane ^ qword)
        match_words = ~mismatch                              # (1, W)

        # --- one-time extraction (the RLU readout, not part of the b-scan) ---
        bit_i = jax.lax.broadcasted_iota(jnp.int32, (W, 32), 1).astype(U32)
        words = jnp.broadcast_to(match_words.reshape(W, 1), (W, 32))
        bits = ((words >> bit_i) & U32(1)) > 0               # (W, 32) slot matches
        match = bits.reshape(1, S) & valid
        any_match = jnp.any(match)
        slot_iota = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
        slot = jnp.min(jnp.where(match, slot_iota, jnp.int32(2**30)))
        onehot = (slot_iota == slot) & match
        vals_row = pool_ref[...].reshape(1, S)               # value lane only
        val = jnp.max(jnp.where(onehot, vals_row, U32(0)))

        already = out_ref[0, 1] > U32(0)

        @pl.when(any_match & jnp.logical_not(already))
        def _write():
            out_ref[0, 0] = val
            out_ref[0, 1] = U32(1)
            out_ref[0, 2] = page.astype(U32)
            out_ref[0, 3] = slot.astype(U32)

    return _kernel


def probe_pages_bitserial(planes, pool, queries, pages, key_bits: int,
                          *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qn, C = pages.shape
    P, b, W = planes.shape
    assert b == key_bits
    S = pool.shape[1]
    assert S == W * 32

    from repro.kernels.ref import fill_fetch_pages
    pages = pages.astype(jnp.int32)
    fetch = fill_fetch_pages(pages)   # filtered steps re-open the resident row

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(qn, C),
        in_specs=[
            pl.BlockSpec((1, b, W),
                         lambda q, c, pages, fetch, queries: (fetch[q, c], 0, 0)),
            # value lane only: block index 1 in the size-1 trailing dim
            pl.BlockSpec((1, S, 1),
                         lambda q, c, pages, fetch, queries: (fetch[q, c], 0, 1)),
        ],
        out_specs=pl.BlockSpec((1, LINE),
                               lambda q, c, pages, fetch, queries: (q, 0)),
    )
    out = pl.pallas_call(
        _make_kernel(key_bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qn, LINE), U32),
        interpret=interpret,
    )(pages, fetch, queries.astype(U32), planes, pool)
    return out[:, 0], out[:, 1] > 0
