"""Performance-optimized HashMem probe kernel (paper §2.2) — TPU-native form.

Paper mechanism: many comparison units pitch-matched under the row buffer
compare *all* keys of the activated row simultaneously (CAM semantics).

TPU adaptation (DESIGN.md §2): one grid step == one row activation.  The
BlockSpec index_map uses the scalar-prefetched page list (the RLU command
stream) to "activate" the page row into VMEM; the 8x128 VPU lanes are the
pitch-matched comparators — the whole row is compared in O(1) vector ops.
The row is the INTERLEAVED (slots, 2) key/value segment of the unified
PageStore, so ONE BlockSpec fetch per chain step exposes both the keys to
compare and the value to latch — exactly the paper's single row activation
serving the whole probe (§2.2, §2.4).  Because TPU lanes are 32-bit, the
compare is element-parallel AND bit-parallel (in DRAM the sense amps force
bit-serial; see probe_bitserial for the faithful bit-serial variant).

Grid: (Q, C) — C (chain position) iterates fastest and accumulates
first-match results into a 128-lane output "cache line" per query, matching
the paper's RLU returning the value padded to a cache line (§2.5).

Output cache-line layout (uint32 lanes): [value, found, page, slot, 0...].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
LINE = 128  # output cache line width (lanes)


def _kernel(pages_ref, fetch_ref, queries_ref, pool_ref, out_ref):
    del fetch_ref   # consumed by the BlockSpec index maps only
    c = pl.program_id(1)
    q = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    page = pages_ref[q, c]
    query = queries_ref[q]
    valid = page >= 0

    kv = pool_ref[...]                                       # (1, S, 2) uint32
    row = kv[..., 0]                                         # (1, S) keys
    match = (row == query) & valid                           # element-parallel compare
    any_match = jnp.any(match)

    slot_iota = jax.lax.broadcasted_iota(jnp.int32, row.shape, 1)
    slot = jnp.min(jnp.where(match, slot_iota, jnp.int32(2**30)))
    onehot = (slot_iota == slot) & match
    val = jnp.max(jnp.where(onehot, kv[..., 1], U32(0)))     # same activated row

    already = out_ref[0, 1] > U32(0)

    @pl.when(any_match & jnp.logical_not(already))
    def _write():
        out_ref[0, 0] = val
        out_ref[0, 1] = U32(1)
        out_ref[0, 2] = page.astype(U32)
        out_ref[0, 3] = slot.astype(U32)


def probe_pages_perf(pool, queries, pages, *, interpret=None):
    """(values (Q,) u32, found (Q,) bool).  ``pool`` is the interleaved
    (P, S, 2) page pool; see module docstring."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels.ref import fill_fetch_pages
    qn, C = pages.shape
    P, S, _ = pool.shape
    pages = pages.astype(jnp.int32)
    # forward-filled fetch schedule: a filtered (-1) step repeats the last
    # block index, so Pallas keeps the row resident instead of re-fetching
    # (zero extra row activations; see ref.fill_fetch_pages)
    fetch = fill_fetch_pages(pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,       # pages, fetch, queries
        grid=(qn, C),
        in_specs=[
            # ONE row activation: keys AND values in a single page fetch
            pl.BlockSpec((1, S, 2),
                         lambda q, c, pages, fetch, queries: (fetch[q, c], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, LINE),
                               lambda q, c, pages, fetch, queries: (q, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qn, LINE), U32),
        interpret=interpret,
    )(pages, fetch, queries.astype(U32), pool)
    return out[:, 0], out[:, 1] > 0
