"""Pure-jnp oracles for the HashMem probe kernels.

All backends implement the same contract over the interleaved pool —
ONE gathered row per chain step exposes the key AND its value (the paper's
row-activation semantics):

  probe_pages(pool (P,S,2) u32 [lane 0 = key, lane 1 = value],
              queries (Q,) u32, pages (Q,C) i32 [-1 padded])
      -> (values (Q,) u32, found (Q,) bool)

First-match-in-chain-order semantics; sentinel keys (EMPTY/TOMBSTONE) never
match because user keys are constrained below them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32
I32 = jnp.int32


def fill_fetch_pages(pages):
    """Forward-fill the -1 holes of a (Q, C) page schedule with the last
    preceding real page id (leading holes fall back to page 0).

    This is the BlockSpec FETCH index for the Pallas kernels.  Pallas skips
    the block copy when the index map returns the same block for consecutive
    grid steps, so a fingerprint-filtered (-1) schedule entry re-"opens" the
    already-resident row instead of activating a new one — the DRAM open-row
    analogue of the paper's row-buffer hit.  Validity still comes from the
    real schedule: the kernel masks its compare with ``pages[q, c] >= 0``,
    so the stale resident row never produces a match."""
    C = pages.shape[1]
    pos = jnp.where(pages >= 0, jnp.arange(C, dtype=I32)[None, :], -1)
    last = jax.lax.cummax(pos, axis=1)
    filled = jnp.take_along_axis(pages, jnp.maximum(last, 0), axis=1)
    return jnp.where(last >= 0, filled, 0).astype(I32)


def probe_pages_ref(pool, queries, pages):
    qn, C = pages.shape
    S = pool.shape[1]
    safe = jnp.maximum(pages, 0)
    rows = pool[safe]                                        # (Q, C, S, 2)
    match = (rows[..., 0] == queries[:, None, None].astype(U32)) \
        & (pages >= 0)[:, :, None]
    flat = match.reshape(qn, C * S)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)                           # first match
    vals = rows[..., 1].reshape(qn, C * S)[jnp.arange(qn), idx]
    return jnp.where(found, vals, U32(0)), found


def probe_bitplanes_ref(planes, pool, queries, pages, key_bits: int):
    """Oracle for the bit-serial backend: operates on the bit-plane layout
    directly (plane-XOR-accumulate), mirroring the kernel's algorithm in
    pure jnp; values come from the interleaved pool's value lane.  Must
    agree with probe_pages_ref on the same logical content."""
    qn, C = pages.shape
    P, b, W = planes.shape
    assert b == key_bits
    S = W * 32
    safe = jnp.maximum(pages, 0)
    pl_rows = planes[safe]                                   # (Q, C, b, W)
    q = queries.astype(U32)
    j = jnp.arange(key_bits, dtype=U32)
    qbits = ((q[:, None] >> j) & U32(1)).astype(bool)        # (Q, b)
    qwords = jnp.where(qbits, U32(0xFFFFFFFF), U32(0))       # (Q, b)
    mism = jnp.bitwise_or.reduce(pl_rows ^ qwords[:, None, :, None], axis=2)  # (Q,C,W)
    mwords = ~mism                                           # (Q, C, W)
    i = jnp.arange(32, dtype=U32)
    bits = ((mwords[..., None] >> i) & U32(1)).astype(bool)  # (Q,C,W,32)
    match = bits.reshape(qn, C, S) & (pages >= 0)[:, :, None]
    flat = match.reshape(qn, C * S)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)
    vrows = pool[safe][..., 1].reshape(qn, C * S)
    vals = vrows[jnp.arange(qn), idx]
    return jnp.where(found, vals, U32(0)), found
