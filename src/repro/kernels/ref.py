"""Pure-jnp oracles for the HashMem probe kernels.

All backends implement the same contract over the interleaved pool —
ONE gathered row per chain step exposes the key AND its value (the paper's
row-activation semantics):

  probe_pages(pool (P,S,2) u32 [lane 0 = key, lane 1 = value],
              queries (Q,) u32, pages (Q,C) i32 [-1 padded])
      -> (values (Q,) u32, found (Q,) bool)

First-match-in-chain-order semantics; sentinel keys (EMPTY/TOMBSTONE) never
match because user keys are constrained below them.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32


def probe_pages_ref(pool, queries, pages):
    qn, C = pages.shape
    S = pool.shape[1]
    safe = jnp.maximum(pages, 0)
    rows = pool[safe]                                        # (Q, C, S, 2)
    match = (rows[..., 0] == queries[:, None, None].astype(U32)) \
        & (pages >= 0)[:, :, None]
    flat = match.reshape(qn, C * S)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)                           # first match
    vals = rows[..., 1].reshape(qn, C * S)[jnp.arange(qn), idx]
    return jnp.where(found, vals, U32(0)), found


def probe_bitplanes_ref(planes, pool, queries, pages, key_bits: int):
    """Oracle for the bit-serial backend: operates on the bit-plane layout
    directly (plane-XOR-accumulate), mirroring the kernel's algorithm in
    pure jnp; values come from the interleaved pool's value lane.  Must
    agree with probe_pages_ref on the same logical content."""
    qn, C = pages.shape
    P, b, W = planes.shape
    assert b == key_bits
    S = W * 32
    safe = jnp.maximum(pages, 0)
    pl_rows = planes[safe]                                   # (Q, C, b, W)
    q = queries.astype(U32)
    j = jnp.arange(key_bits, dtype=U32)
    qbits = ((q[:, None] >> j) & U32(1)).astype(bool)        # (Q, b)
    qwords = jnp.where(qbits, U32(0xFFFFFFFF), U32(0))       # (Q, b)
    mism = jnp.bitwise_or.reduce(pl_rows ^ qwords[:, None, :, None], axis=2)  # (Q,C,W)
    mwords = ~mism                                           # (Q, C, W)
    i = jnp.arange(32, dtype=U32)
    bits = ((mwords[..., None] >> i) & U32(1)).astype(bool)  # (Q,C,W,32)
    match = bits.reshape(qn, C, S) & (pages >= 0)[:, :, None]
    flat = match.reshape(qn, C * S)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)
    vrows = pool[safe][..., 1].reshape(qn, C * S)
    vals = vrows[jnp.arange(qn), idx]
    return jnp.where(found, vals, U32(0)), found
