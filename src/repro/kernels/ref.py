"""Pure-jnp oracles for the HashMem probe kernels.

All backends implement the same contract:

  probe_pages(key_pages (P,S) u32, val_pages (P,S) u32,
              queries (Q,) u32, pages (Q,C) i32 [-1 padded])
      -> (values (Q,) u32, found (Q,) bool)

First-match-in-chain-order semantics; sentinel keys (EMPTY/TOMBSTONE) never
match because user keys are constrained below them.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32


def probe_pages_ref(key_pages, val_pages, queries, pages):
    qn, C = pages.shape
    S = key_pages.shape[1]
    safe = jnp.maximum(pages, 0)
    rows = key_pages[safe]                                   # (Q, C, S)
    vrows = val_pages[safe]                                  # (Q, C, S)
    match = (rows == queries[:, None, None].astype(U32)) & (pages >= 0)[:, :, None]
    flat = match.reshape(qn, C * S)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)                           # first match
    vals = vrows.reshape(qn, C * S)[jnp.arange(qn), idx]
    return jnp.where(found, vals, U32(0)), found


def probe_bitplanes_ref(planes, val_pages, queries, pages, key_bits: int):
    """Oracle for the bit-serial backend: operates on the bit-plane layout
    directly (plane-XOR-accumulate), mirroring the kernel's algorithm in
    pure jnp.  Must agree with probe_pages_ref on the same logical content."""
    qn, C = pages.shape
    P, b, W = planes.shape
    assert b == key_bits
    S = W * 32
    safe = jnp.maximum(pages, 0)
    pl_rows = planes[safe]                                   # (Q, C, b, W)
    q = queries.astype(U32)
    j = jnp.arange(key_bits, dtype=U32)
    qbits = ((q[:, None] >> j) & U32(1)).astype(bool)        # (Q, b)
    qwords = jnp.where(qbits, U32(0xFFFFFFFF), U32(0))       # (Q, b)
    mism = jnp.bitwise_or.reduce(pl_rows ^ qwords[:, None, :, None], axis=2)  # (Q,C,W)
    mwords = ~mism                                           # (Q, C, W)
    i = jnp.arange(32, dtype=U32)
    bits = ((mwords[..., None] >> i) & U32(1)).astype(bool)  # (Q,C,W,32)
    match = bits.reshape(qn, C, S) & (pages >= 0)[:, :, None]
    flat = match.reshape(qn, C * S)
    found = jnp.any(flat, axis=1)
    idx = jnp.argmax(flat, axis=1)
    vrows = val_pages[safe].reshape(qn, C * S)
    vals = vrows[jnp.arange(qn), idx]
    return jnp.where(found, vals, U32(0)), found
