import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the 512-chip production meshes;
# smoke tests and benchmarks see the single real CPU device.
if os.environ.get("REPRO_HOST_DEVICES"):   # test-scale override (still pre-jax)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_HOST_DEVICES"])

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Per cell this produces a JSON artifact with:
  * memory_analysis (bytes/device: argument, output, temp, peak)  [fits proof]
  * cost_analysis   (per-device HLO FLOPs / bytes accessed)
  * collective bytes parsed from the partitioned HLO text, by op kind
  * compile wall time, HLO sizes

Modes (--probe):
  full   — production lowering (scan over layer units).  Memory + collective
           schedule are exact here; FLOPs are NOT (XLA counts a while-loop
           body once — verified; see EXPERIMENTS.md §Roofline method).
  unit1 / unit2 — cost probes: scan_layers=False, inner_unroll=True with 1 or
           2 layer-units.  roofline.py extrapolates: per_unit = c2 - c1;
           total = c1 + (n_units - 1) * per_unit  (linear in depth, exact for
           the layer-homogeneous stacks used here).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ServeConfig, get_config, cells
from repro.configs.base import OptimConfig
from repro.distributed import steps
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.optim import init_opt_state

WHISPER_DECODE_ENC_FRAMES = 1504  # 30 s of audio (whisper frame rate), padded

# per-arch training-regime overrides (memory fit on 16GB v5e; DESIGN.md §5)
TRAIN_OVERRIDES = {
    "llama4-maverick-400b-a17b": dict(param_dtype="bfloat16"),
    "jamba-v0.1-52b": dict(param_dtype="bfloat16"),
}
OPTIM_OVERRIDES = {
    "llama4-maverick-400b-a17b": OptimConfig(state_dtype="bfloat16"),
    "jamba-v0.1-52b": OptimConfig(state_dtype="bfloat16"),
}

COLLECTIVE_RE = re.compile(
    r"(\S+)\s*=\s*(\w[\w<>\[\], ]*)\s(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)")
SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|u32|s8|u8|pred|f64|s64|u64)\[([\d,]*)\]")

DTYPE_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective bytes by op kind from partitioned HLO."""
    out = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"= ((?:\([^)]*\)|\S+)) (all-gather|all-reduce|"
                      r"reduce-scatter|all-to-all|collective-permute)(-start)?\(",
                      line)
        if not m:
            continue
        kind = m.group(2)
        shapes = SHAPE_RE.findall(m.group(1))
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        # ring all-reduce moves ~2x the payload
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] = out.get(kind, 0.0) + nbytes * factor
        out.setdefault("_count_" + kind, 0)
        out["_count_" + kind] += 1
    out["total_bytes"] = sum(v for k, v in out.items()
                             if not k.startswith("_") and k != "total_bytes")
    return out


def _cfg_for(arch: str, shape_name: str, probe: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        cfg = cfg.replace(**TRAIN_OVERRIDES.get(arch, {}))
    else:
        cfg = cfg.replace(param_dtype="bfloat16")  # inference weights bf16
    if os.environ.get("REPRO_OPT"):
        # hillclimb configuration (EXPERIMENTS.md §Perf): EP MoE dispatch +
        # sqrt-remat for the mLSTM matrix-memory scan
        if cfg.num_experts:
            cfg = cfg.replace(moe_impl="ep")
        if cfg.family == "ssm":
            cfg = cfg.replace(mlstm_scan_groups=8)
    if probe in ("unit1", "unit2"):
        from repro.models.transformer import scan_unit_size
        unit = scan_unit_size(cfg)
        n = unit if probe == "unit1" else 2 * unit
        kw = dict(num_layers=n, scan_layers=False, inner_unroll=True)
        if cfg.is_encoder_decoder:
            kw["num_encoder_layers"] = 1 if probe == "unit1" else 2
        # coarser mamba chunking keeps the unrolled-probe HLO tractable;
        # selective-scan FLOPs are chunk-invariant to first order (only the
        # associative-combine log factor moves, <3% of the block's FLOPs).
        if shape.kind in ("train", "prefill"):
            kw["mamba_chunk"] = min(max(shape.seq_len // 8, 64), 2048)
        # mLSTM unrolled-bwd probes are intractable to compile; keep the
        # chunk scan and let roofline.py add the analytic per-chunk term.
        if cfg.family == "ssm":
            kw["mlstm_unroll"] = False
        cfg = cfg.replace(**kw)
    return cfg, shape


def lower_cell(arch: str, shape_name: str, mesh, probe: str = "full"):
    """Lower+compile one cell; returns (compiled, meta)."""
    cfg, shape = _cfg_for(arch, shape_name, probe)
    meta = {"arch": arch, "shape": shape_name, "probe": probe,
            "num_layers": cfg.num_layers, "mesh": dict(mesh.shape)}

    # §Perf iteration 3 tried seq_shard=False for the ssm family (hypothesis:
    # the recurrent blocks re-gather full S anyway) — REFUTED: without SP the
    # TP'd projections move 6x MORE bytes (full-S activations per layer).
    # SP stays on everywhere.
    seq_shard = True
    if shape.kind in ("train", "prefill"):
        sds = model.input_specs(cfg, shape)
        if shape.kind == "train":
            oc = OPTIM_OVERRIDES.get(arch, OptimConfig())
            _, jitted, pshard, oshard = steps.build_train_step(
                cfg, oc, mesh, seq_shard=seq_shard)
            def _init(k):
                p = model.init_params(cfg, k)
                return p, init_opt_state(p, oc)
            params_sds, opt_sds = jax.eval_shape(_init, jax.random.PRNGKey(0))
            lowered = jitted(sds).lower(params_sds, opt_sds, sds)
        else:
            # prefill: forward trunk + last-position logits
            from repro.distributed import sharding as shd
            from jax.sharding import NamedSharding, PartitionSpec as P
            pshard = shd.named(mesh, shd.param_specs(cfg, mesh))
            ctx = shd.ShardCtx(mesh, seq_shard=seq_shard)

            def prefill(params, batch):
                x, _ = model.forward(params, cfg, batch, shard_ctx=ctx)
                return model.logits_fn(params, cfg, x[:, -1:])

            bshard = {k: NamedSharding(
                mesh, P(shd.batch_spec(mesh, v.shape[0]),
                        *([None] * (v.ndim - 1)))) for k, v in sds.items()}
            params_sds = jax.eval_shape(
                lambda k: model.init_params(cfg, k), jax.random.PRNGKey(0))
            lowered = jax.jit(prefill, in_shardings=(pshard, bshard)) \
                .lower(params_sds, sds)
    else:  # decode
        scfg = ServeConfig(model=cfg, shape=shape)
        _, jitted, ctx, pshard = steps.build_serve_step(cfg, scfg, mesh)
        B = shape.global_batch
        params_sds = jax.eval_shape(
            lambda k: model.init_params(cfg, k), jax.random.PRNGKey(0))
        if cfg.is_encoder_decoder:
            frames = jax.ShapeDtypeStruct(
                (B, WHISPER_DECODE_ENC_FRAMES, cfg.d_model), jnp.bfloat16)
            states_sds = jax.eval_shape(
                lambda p, f: model.init_decode_states(p, cfg, B, ctx,
                                                      enc_frames=f),
                params_sds, frames)
        else:
            states_sds = jax.eval_shape(
                lambda p: model.init_decode_states(p, cfg, B, ctx), params_sds)
        inp = model.input_specs(cfg, shape, scfg, ctx)
        meta["n_pages"] = ctx.n_pages
        meta["pool_pages"] = ctx.pool_pages
        lowered = jitted(states_sds).lower(
            params_sds, states_sds, inp["tokens"], inp["pos"],
            inp["block_table"])

    t0 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = time.time() - t0
    return compiled, meta


def analyze(compiled, meta) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):           # older jax: list of per-program dicts
        ca = ca[0] if ca else {}
    rec = dict(meta)
    rec["flops_per_device"] = float(ca.get("flops", 0.0))
    rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "peak_memory_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                rec[f] = int(v)
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis_error"] = str(e)
    txt = compiled.as_text()
    rec["collectives"] = parse_collectives(txt)
    rec["hlo_chars"] = len(txt)
    return rec


def _mesh_for(mesh_kind: str):
    """Production mesh, or a test-scale override via REPRO_MESH=d,m[,p]."""
    ov = os.environ.get("REPRO_MESH")
    if ov:
        dims = tuple(int(x) for x in ov.split(","))
        from repro.launch.mesh import make_mesh
        if mesh_kind == "multi":
            return make_mesh((2,) + dims, ("pod", "data", "model"))
        return make_mesh(dims, ("data", "model"))
    return make_production_mesh(multi_pod=(mesh_kind == "multi"))


def report_name(arch, shape_name, mesh_kind, probe) -> str:
    """Canonical per-cell report filename (tests import this — keep in sync)."""
    return f"{arch}__{shape_name}__{mesh_kind}__{probe}.json"


def run_cell(arch, shape_name, mesh_kind, probe, out_dir: Path):
    name = report_name(arch, shape_name, mesh_kind, probe)
    out = out_dir / name
    if out.exists():
        print(f"[skip] {name}")
        return json.loads(out.read_text())
    t0 = time.time()
    try:
        mesh = _mesh_for(mesh_kind)
        compiled, meta = lower_cell(arch, shape_name, mesh, probe)
        rec = analyze(compiled, meta)
        rec["ok"] = True
        del compiled
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "probe": probe,
               "mesh_kind": mesh_kind, "ok": False, "error": repr(e)[:2000]}
    rec["wall_s"] = time.time() - t0
    out_dir.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    status = "ok" if rec.get("ok") else "FAIL"
    print(f"[{status}] {name}  wall={rec['wall_s']:.1f}s "
          f"flops/dev={rec.get('flops_per_device', 0):.3e} "
          f"coll={rec.get('collectives', {}).get('total_bytes', 0):.3e}B")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--probe", default="full",
                    choices=["full", "unit1", "unit2", "all"])
    ap.add_argument("--all", action="store_true", help="all assigned cells")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    probes = ["full", "unit1", "unit2"] if args.probe == "all" else [args.probe]

    failures = 0
    jobs = []
    for pr in probes:                      # all 'full' cells first (deliverable e)
        for arch, shape_name in todo:
            for mk in meshes:
                if pr != "full" and mk == "multi":
                    continue  # cost probes are single-pod (roofline table)
                jobs.append((arch, shape_name, mk, pr))
    for arch, shape_name, mk, pr in jobs:
        rec = run_cell(arch, shape_name, mk, pr, out_dir)
        failures += 0 if rec.get("ok") else 1
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
