"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first jax use.

``jax.sharding.AxisType`` only exists in newer jax releases; older ones
(e.g. 0.4.x) neither expose it nor accept ``axis_types=`` in
``jax.make_mesh``.  ``_make_mesh_compat`` papers over the difference so the
same call sites work on both.
"""
from __future__ import annotations

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _make_mesh_compat(shape, axes):
    if _AXIS_TYPE is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(_AXIS_TYPE.Auto,) * len(axes))
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh_compat(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary (test-sized) mesh with the same axis semantics."""
    return _make_mesh_compat(shape, axes)


def make_serving_mesh(num_shards: int = 0, axis: str = "model"):
    """1-D mesh for the mesh-backed ServingEngine: ``num_shards`` devices on
    the channel ('model') axis, one HashMem shard each.  0 -> all devices.
    """
    n = num_shards or len(jax.devices())
    assert n <= len(jax.devices()), \
        f"serving mesh wants {n} devices, have {len(jax.devices())}"
    return _make_mesh_compat((n,), (axis,))
