"""Serving CLI: thin front-end over the request engine (repro.serving).

Two modes:

  * ``decode`` (default) — batched LM decode with the HashMem-managed paged
    KV cache.  Slot lifecycle and admission come from the serving engine's
    ``SlotPool``; all page-table traffic in a step is COALESCED — one
    batched HashMem delete for every sequence finishing in the step
    (``free_seqs``) and one batched insert for every sequence admitted in
    it (``alloc_seqs``) — and ``PageTableManager.tick()`` runs the
    compaction triggers on the step clock, not just on frees.

  * ``kv`` — the multi-tenant continuous-batching KV engine under a
    YCSB-style load (repro.serving.engine + loadgen): per-tenant workloads
    A-F, admission quotas, step-level op coalescing, JSON metrics.

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 12 --batch 4 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --mode kv \
      --workloads A,B,E --requests 64 --slots 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ServeConfig, get_config, smoke_config
from repro.configs.base import ShapeConfig
from repro.core.paged_kv import PageTableManager
from repro.distributed import steps as dsteps
from repro.launch.mesh import make_mesh
from repro.models import model
from repro.serving.engine import SlotPool


def serve(cfg, mesh, *, batch=4, horizon=256, page_tokens=32, requests=8,
          max_new=16, prompt_len=8, seed=0, backend="ref", verbose=True,
          compact_chain_len=None):
    shape = ShapeConfig("serve", horizon, batch, "decode")
    scfg = ServeConfig(model=cfg, shape=shape, kv_page_tokens=page_tokens)
    serve_step, jitted, ctx, pshard = dsteps.build_serve_step(cfg, scfg, mesh)
    Dm = 1
    for a in ctx.channel_axes:
        Dm *= mesh.shape[a]
    n_groups = 1
    for a in ctx.batch_axes:
        n_groups *= mesh.shape[a]
    b_loc = batch // n_groups

    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    states = model.init_decode_states(params, cfg, batch, ctx,
                                      kv_dtype=jnp.float32)
    step_fn = jitted(states)

    mgr = PageTableManager(ctx.pool_pages, num_channels=Dm,
                           num_groups=n_groups, backend=backend,
                           compact_chain_len=compact_chain_len)
    rng = np.random.default_rng(seed)

    pool = SlotPool(batch)
    block_tables = np.zeros((batch, ctx.n_pages), np.int32)
    pos = np.zeros((batch,), np.int32)
    tokens = np.zeros((batch, 1), np.int32)
    done = []
    t0 = time.time()
    steps_run = 0

    def place(newly):
        """Coalesced admission: ONE page-table insert for every sequence
        admitted this step, then per-slot decode-state reset."""
        if not newly:
            return
        phys = mgr.alloc_seqs([(req["id"], ctx.n_pages, slot // b_loc)
                               for slot, req in newly])
        for slot, req in newly:
            block_tables[slot] = phys[req["id"]]
            pos[slot] = 0
            tokens[slot, 0] = req["prompt"][0]
            req["fed"] = 1

    for i in range(requests):
        pool.submit({"id": i,
                     "prompt": rng.integers(0, cfg.vocab_size,
                                            prompt_len).tolist(),
                     "out": []})
    place(pool.active())

    while not pool.idle():
        bt = jnp.asarray(block_tables)
        nt, logits, states = step_fn(params, states, jnp.asarray(tokens),
                                     jnp.asarray(pos), bt)
        nt = np.asarray(nt)
        steps_run += 1
        finished = []
        for b, req in pool.active():
            pos[b] += 1
            if req["fed"] < len(req["prompt"]):
                tokens[b, 0] = req["prompt"][req["fed"]]   # prompt feeding
                req["fed"] += 1
            else:
                req["out"].append(int(nt[b]))
                tokens[b, 0] = int(nt[b])
                if len(req["out"]) >= max_new or pos[b] >= horizon - 1:
                    finished.append((b, req))
        # tombstone + recycle: ONE batched delete for the whole step
        mgr.free_seqs([req["id"] for _, req in finished])
        for b, req in finished:
            pool.release(b)
            done.append(req)
        place(pool.refill())
        mgr.tick()             # step-clock compaction (not only on frees)

    dt_val = time.time() - t0
    if verbose:
        print(f"served {len(done)} requests in {steps_run} decode steps, "
              f"{dt_val:.1f}s; live pages after drain: {mgr.live_pages()}; "
              f"page-table grows={mgr.grow_events} "
              f"compactions={mgr.compact_events}")
        for req in done[:4]:
            print(f"  req {req['id']}: prompt {req['prompt'][:4]}... -> "
                  f"out {req['out'][:8]}")
    return done, mgr, steps_run


def serve_kv(*, workloads="A", tenants=None, requests=64, slots=16,
             shards=1, record_count=1024, ops_per_request=4,
             max_pending=0, tenant_slots=0, seed=0, backend="ref",
             mesh_shards=0, pipeline=1, fused_tick=None, verbose=True,
             trace_out=None, metrics_prom=None):
    """Thin driver over the multi-tenant KV serving engine: one tenant per
    workload letter (comma-separated), YCSB load phase, then a drained
    continuous-batching run.  ``mesh_shards`` > 0 routes the table through
    the RLU mesh path (one shard per device on a 1-D 'model' mesh — needs
    that many jax devices, e.g. via
    XLA_FLAGS=--xla_force_host_platform_device_count=N); ``pipeline`` > 1
    enables multi-tick op pipelining; ``fused_tick=False`` falls back from
    the fused whole-tick megakernel (the mesh default: ONE shard_map per
    tick) to one shard_map call per phase.  ``trace_out`` turns on tick
    tracing and writes Chrome/Perfetto trace-event JSON there after the
    drain (open in https://ui.perfetto.dev or inspect with
    tools/trace_report.py); ``metrics_prom`` writes the Prometheus text
    exposition of the run's metrics.  Returns (engine, snapshot)."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import build_ycsb_engine

    wls = [w.strip().upper() for w in workloads.split(",") if w.strip()]
    n_tenants = tenants or len(wls)
    mesh = make_serving_mesh(mesh_shards) if mesh_shards else None
    eng, gens = build_ycsb_engine(
        [wls[i % len(wls)] for i in range(n_tenants)], slots=slots,
        shards=shards, record_count=record_count,
        ops_per_request=ops_per_request, backend=backend, seed=seed,
        max_pending=max_pending, tenant_slots=tenant_slots, mesh=mesh,
        pipeline_depth=pipeline, fused_tick=fused_tick,
        trace=bool(trace_out))
    per = requests // n_tenants
    reqs = [r for g in gens for r in g.requests(per)]
    eng.submit_all(reqs)
    snap = eng.run()
    if trace_out:
        n = eng.export_trace(trace_out, workloads=workloads)
        if verbose:
            print(f"wrote {n} trace events -> {trace_out}")
    if metrics_prom:
        with open(metrics_prom, "w") as f:
            f.write(eng.metrics.to_prom())
        if verbose:
            print(f"wrote Prometheus exposition -> {metrics_prom}")
    if verbose:
        print(json.dumps({**snap, "engine": eng.stats()}, indent=2,
                         default=str))
    return eng, snap


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="decode", choices=["decode", "kv"])
    ap.add_argument("--arch", default=None, help="(decode mode) model arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--horizon", type=int, default=256)
    ap.add_argument("--page-tokens", type=int, default=32)
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "perf", "area", "bitserial"])
    ap.add_argument("--mesh", type=int, nargs="*", default=None)
    ap.add_argument("--compact-chain-len", type=int, default=None,
                    help="page-table compaction when any bucket chain "
                         "exceeds this many pages (skewed frees); default: "
                         "tombstone-fraction trigger only")
    # kv-mode knobs (repro.serving)
    ap.add_argument("--workloads", default="A",
                    help="(kv mode) comma-separated YCSB letters, one "
                         "tenant per entry, e.g. A,B,E")
    ap.add_argument("--slots", type=int, default=16,
                    help="(kv mode) concurrent request slots")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--record-count", type=int, default=1024)
    ap.add_argument("--ops-per-request", type=int, default=4)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="(kv mode) >0: mesh-backed shards, one per device "
                         "on a 1-D 'model' mesh (set XLA_FLAGS to force "
                         "host devices); 0: host-routed shards")
    ap.add_argument("--pipeline", type=int, default=1,
                    help="(kv mode) multi-tick op pipelining depth "
                         "(1 = off)")
    ap.add_argument("--no-fused-tick", action="store_true",
                    help="(kv mode) use one shard_map call per phase "
                         "instead of the fused whole-tick megakernel "
                         "(mesh default)")
    ap.add_argument("--trace-out", default=None,
                    help="(kv mode) enable tick tracing and write "
                         "Chrome/Perfetto trace-event JSON here "
                         "(tools/trace_report.py reads it)")
    ap.add_argument("--metrics-prom", default=None,
                    help="(kv mode) write the Prometheus text exposition "
                         "of the run's metrics here")
    args = ap.parse_args()

    if args.mode == "kv":
        serve_kv(workloads=args.workloads, requests=args.requests,
                 slots=args.slots, shards=args.shards,
                 record_count=args.record_count,
                 ops_per_request=args.ops_per_request,
                 backend=args.backend, mesh_shards=args.mesh_shards,
                 pipeline=args.pipeline,
                 fused_tick=False if args.no_fused_tick else None,
                 trace_out=args.trace_out, metrics_prom=args.metrics_prom)
        return

    if args.arch is None:
        ap.error("--arch is required in decode mode")
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh(tuple(args.mesh) if args.mesh else (1, 1),
                     ("data", "model"))
    serve(cfg, mesh, batch=args.batch, requests=args.requests,
          max_new=args.max_new, horizon=args.horizon,
          page_tokens=args.page_tokens, backend=args.backend,
          compact_chain_len=args.compact_chain_len)


if __name__ == "__main__":
    main()
