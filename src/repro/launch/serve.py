"""Serving driver: batched decode with the HashMem-managed paged KV cache.

Continuous-batching-lite: a fixed decode batch of B slots; when a sequence
finishes, its pages are tombstone-freed through the HashMem page table
(paper §2.5 deletion) and a new request takes the slot, with pages allocated
by pim_malloc from the per-channel free lists.

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 12 --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ServeConfig, get_config, smoke_config
from repro.configs.base import ShapeConfig
from repro.core.paged_kv import PageTableManager
from repro.distributed import steps as dsteps
from repro.launch.mesh import make_mesh
from repro.models import model


def serve(cfg, mesh, *, batch=4, horizon=256, page_tokens=32, requests=8,
          max_new=16, prompt_len=8, seed=0, backend="ref", verbose=True,
          compact_chain_len=None):
    shape = ShapeConfig("serve", horizon, batch, "decode")
    scfg = ServeConfig(model=cfg, shape=shape, kv_page_tokens=page_tokens)
    serve_step, jitted, ctx, pshard = dsteps.build_serve_step(cfg, scfg, mesh)
    Dm = 1
    for a in ctx.channel_axes:
        Dm *= mesh.shape[a]
    n_groups = 1
    for a in ctx.batch_axes:
        n_groups *= mesh.shape[a]
    b_loc = batch // n_groups

    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    states = model.init_decode_states(params, cfg, batch, ctx,
                                      kv_dtype=jnp.float32)
    step_fn = jitted(states)

    mgr = PageTableManager(ctx.pool_pages, num_channels=Dm,
                           num_groups=n_groups, backend=backend,
                           compact_chain_len=compact_chain_len)
    rng = np.random.default_rng(seed)

    # request queue
    queue = [{"id": i,
              "prompt": rng.integers(0, cfg.vocab_size, prompt_len).tolist(),
              "out": []} for i in range(requests)]
    slots = [None] * batch
    block_tables = np.zeros((batch, ctx.n_pages), np.int32)
    pos = np.zeros((batch,), np.int32)
    tokens = np.zeros((batch, 1), np.int32)
    done = []
    t0 = time.time()
    steps_run = 0

    def admit(slot):
        if not queue:
            slots[slot] = None
            return
        req = queue.pop(0)
        req["fed"] = 0
        slots[slot] = req
        phys = mgr.alloc_seq(req["id"], ctx.n_pages, group=slot // b_loc)
        block_tables[slot] = phys
        pos[slot] = 0
        tokens[slot, 0] = req["prompt"][0]
        req["fed"] = 1

    for b in range(batch):
        admit(b)

    while any(s is not None for s in slots):
        bt = jnp.asarray(block_tables)
        nt, logits, states = step_fn(params, states, jnp.asarray(tokens),
                                     jnp.asarray(pos), bt)
        nt = np.asarray(nt)
        steps_run += 1
        for b, req in enumerate(slots):
            if req is None:
                continue
            pos[b] += 1
            if req["fed"] < len(req["prompt"]):
                tokens[b, 0] = req["prompt"][req["fed"]]   # prompt feeding
                req["fed"] += 1
            else:
                req["out"].append(int(nt[b]))
                tokens[b, 0] = int(nt[b])
                if len(req["out"]) >= max_new or pos[b] >= horizon - 1:
                    mgr.free_seq(req["id"])                # tombstone + recycle
                    done.append(req)
                    admit(b)

    dt_val = time.time() - t0
    if verbose:
        print(f"served {len(done)} requests in {steps_run} decode steps, "
              f"{dt_val:.1f}s; live pages after drain: {mgr.live_pages()}; "
              f"page-table grows={mgr.grow_events} "
              f"compactions={mgr.compact_events}")
        for req in done[:4]:
            print(f"  req {req['id']}: prompt {req['prompt'][:4]}... -> "
                  f"out {req['out'][:8]}")
    return done, mgr, steps_run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--horizon", type=int, default=256)
    ap.add_argument("--page-tokens", type=int, default=32)
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "perf", "area", "bitserial"])
    ap.add_argument("--mesh", type=int, nargs="*", default=None)
    ap.add_argument("--compact-chain-len", type=int, default=None,
                    help="page-table compaction when any bucket chain "
                         "exceeds this many pages (skewed frees); default: "
                         "tombstone-fraction trigger only")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh(tuple(args.mesh) if args.mesh else (1, 1),
                     ("data", "model"))
    serve(cfg, mesh, batch=args.batch, requests=args.requests,
          max_new=args.max_new, horizon=args.horizon,
          page_tokens=args.page_tokens, backend=args.backend,
          compact_chain_len=args.compact_chain_len)


if __name__ == "__main__":
    main()
