"""Training driver: data pipeline -> pjit train_step, with checkpointing,
failure injection/restart, straggler monitoring and gradient compression.

CPU-scale usage (examples/tests):
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --ckpt-dir /tmp/ck --inject-failure-at 20
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_config, smoke_config
from repro.configs.base import OptimConfig, ShapeConfig
from repro.data import SyntheticLMData, make_batch_specs
from repro.distributed import steps as dsteps
from repro.distributed.fault_tolerance import (
    FailureInjector, InjectedFailure, RestartPolicy, StragglerMonitor)
from repro.launch.mesh import make_mesh


def train(cfg, shape, oc, mesh, *, num_steps, ckpt_dir, ckpt_every=50,
          log_every=10, inject=None, seed=0, grad_compression="none",
          seq_shard=False, verbose=True):
    ckpt = Checkpointer(ckpt_dir)
    injector = FailureInjector(tuple(inject or ()))
    policy = RestartPolicy(max_restarts=4)
    monitor = StragglerMonitor()

    _, jitted, pshard, oshard = dsteps.build_train_step(
        cfg, oc, mesh, seq_shard=seq_shard, grad_compression=grad_compression)

    data = SyntheticLMData(cfg, shape, seed=seed)
    sample = data.batch_at(0)
    step_fn = jitted(sample)
    bshard = make_batch_specs(mesh, sample)

    losses = {}
    while True:  # restart loop
        try:
            start = ckpt.latest_step()
            if start is None:
                params, opt_state = dsteps.init_train_state(
                    cfg, oc, mesh, jax.random.PRNGKey(seed))
                start = 0
            else:
                target = _restore_tree_shapes(cfg, oc, seed)
                restored = ckpt.restore(
                    start, target, {"params": pshard, "opt": oshard})
                params, opt_state = restored["params"], restored["opt"]
                if verbose:
                    print(f"[restore] resumed from step {start}")
            for step in range(start, num_steps):
                injector.check(step)
                batch = {k: jax.device_put(v, bshard[k])
                         for k, v in data.batch_at(step).items()}
                t0 = time.time()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt_s = time.time() - t0
                monitor.observe(step, dt_s)
                losses[step] = loss
                if verbose and (step % log_every == 0 or step == num_steps - 1):
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"grad_norm {float(metrics['grad_norm']):7.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt_s*1e3:7.1f} ms")
                if ckpt_every and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state})
            ckpt.save(num_steps, {"params": params, "opt": opt_state},
                      blocking=True)
            ckpt.wait()
            return params, opt_state, losses, monitor, policy
        except InjectedFailure as e:
            if verbose:
                print(f"[failure] {e}; restart {policy.restarts + 1}")
            if not policy.on_failure(e):
                raise


def _restore_tree_shapes(cfg, oc, seed):
    from repro.models import model
    from repro.optim import init_opt_state

    def f(k):
        p = model.init_params(cfg, k)
        return {"params": p, "opt": init_opt_state(p, oc)}
    return jax.eval_shape(f, jax.random.PRNGKey(seed))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, nargs="*", default=None)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--mesh", type=int, nargs="*", default=None,
                    help="mesh shape, e.g. --mesh 2 4 (data model)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    oc = OptimConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                     total_steps=args.steps)
    if args.mesh:
        names = ("data", "model")[:len(args.mesh)] if len(args.mesh) <= 2 \
            else ("pod", "data", "model")
        mesh = make_mesh(args.mesh, names)
    else:
        mesh = make_mesh((1, 1), ("data", "model"))

    _, _, losses, monitor, policy = train(
        cfg, shape, oc, mesh, num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, inject=args.inject_failure_at,
        grad_compression=args.grad_compression)
    ls = sorted(losses)
    print(f"first loss {losses[ls[0]]:.4f} -> last loss {losses[ls[-1]]:.4f}; "
          f"restarts={policy.restarts} stragglers={len(monitor.flagged)}")


if __name__ == "__main__":
    main()
