"""Model zoo: all 10 assigned architectures via repro.models.model."""
