"""GQA attention: chunked (flash-style) train/prefill + decode paths.

The train/prefill path never materializes the full (S, S) score matrix: it
scans over KV chunks carrying (max, sum, acc) — the standard online-softmax
used by FlashAttention, expressed in pure jnp so XLA fuses it per chunk.
Sliding-window (h2o-danube) and causal masks are applied per chunk.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, head_rms_norm, leaf, rope

NEG_INF = -1e30


def init(key, cfg):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (d, H, hd), ("embed", "heads", "head_dim")),
        "wk": dense_init(ks[1], d, (d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": dense_init(ks[2], d, (d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": dense_init(ks[3], H * hd, (H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_scale"] = leaf(jnp.ones((hd,), jnp.float32), "head_dim")
        p["k_scale"] = leaf(jnp.ones((hd,), jnp.float32), "head_dim")
    return p


def qkv(params, cfg, x, positions):
    """x (B,S,d) -> q (B,S,H,hd), k,v (B,S,K,hd), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = head_rms_norm(q, params["q_scale"], cfg.norm_eps)
        k = head_rms_norm(k, params["k_scale"], cfg.norm_eps)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(params, cfg, o):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype))


def _chunk_attend(q, k, v, qpos, kpos, causal, window):
    """One (q-chunk, kv-chunk) tile. q (B,cq,K,G,hd) k/v (B,ck,K,hd).

    Returns scores-applied partials (m, l, acc) for online softmax.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgh,bckh->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale          # (B,K,G,cq,ck)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                # (B,K,G,cq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgqc,bckh->bkgqh", p, v.astype(jnp.float32))
    return m, l, acc


def chunked_attention(q, k, v, cfg, *, causal=True, chunk=None,
                      q_offset=0, kv_len=None):
    """Flash-style attention.  q (B,Sq,H,hd), k/v (B,Skv,K,hd).

    Online softmax over KV chunks; GQA via head grouping.  Skv must be a
    multiple of the chunk size (callers pad shapes; assigned shapes are
    powers of two).
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    chunk = min(chunk or cfg.attn_chunk, Skv)
    if Skv % chunk:
        import math
        chunk = math.gcd(chunk, Skv)
    n_chunks = Skv // chunk

    qg = q.reshape(B, Sq, K, G, hd)
    qpos = q_offset + jnp.arange(Sq)
    window = cfg.sliding_window

    def body(carry, ck_idx):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ck_idx * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, ck_idx * chunk, chunk, 1)
        kpos = ck_idx * chunk + jnp.arange(chunk)
        mc, lc, ac = _chunk_attend(qg, ks, vs, qpos, kpos, causal, window)
        m_new = jnp.maximum(m, mc)
        r_old = jnp.exp(m - m_new)
        r_new = jnp.exp(mc - m_new)
        l_new = l * r_old + lc * r_new
        acc_new = acc * r_old[..., None] + ac * r_new[..., None]
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)
    from repro.models.scan_utils import maybe_scan
    (m, l, acc), _ = maybe_scan(body, (m0, l0, a0), jnp.arange(n_chunks),
                                unroll=cfg.inner_unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,K,G,Sq,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def decode_attention_dense(q, k_cache, v_cache, seq_len, cfg):
    """Single-token decode vs a dense cache.  q (B,1,H,hd),
    k_cache/v_cache (B,Smax,K,hd), seq_len (B,) valid lengths."""
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (hd ** -0.5)
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None, :] < seq_len[:, None]
    if cfg.sliding_window:
        valid &= pos[None, :] >= seq_len[:, None] - cfg.sliding_window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
