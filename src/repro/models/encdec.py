"""Encoder-decoder stack (whisper-tiny).

The audio conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, frames, d_model) + sinusoidal positions.
The decoder is a causal transformer with cross-attention; decode uses the
paged KV cache for self-attention and dense (precomputed) encoder KV for
cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import paged_kv
from repro.models import attention, mlp
from repro.models.layers import layer_norm, norm_init, sinusoid_positions
from repro.models.transformer import DecodeCtx, _paged_attn_sub


def init_cross(key, cfg):
    return attention.init(key, cfg)


def init_encoder_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "norm1": norm_init(cfg.d_model, centered=True),
        "attn": attention.init(ks[0], cfg),
        "norm2": norm_init(cfg.d_model, centered=True),
        "ffn": mlp.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def init_decoder_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg.d_model, centered=True),
        "attn": attention.init(ks[0], cfg),
        "norm_x": norm_init(cfg.d_model, centered=True),
        "cross": attention.init(ks[1], cfg),
        "norm2": norm_init(cfg.d_model, centered=True),
        "ffn": mlp.init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_stacks(key, cfg):
    from repro.models.layers import Axes, is_leaf
    ke, kd = jax.random.split(key)
    enc = [init_encoder_layer(k, cfg)
           for k in jax.random.split(ke, cfg.num_encoder_layers)]
    dec = [init_decoder_layer(k, cfg)
           for k in jax.random.split(kd, cfg.num_layers)]
    stack = lambda layers: jax.tree.map(
        lambda *xs: (jnp.stack([x[0] for x in xs]),
                     Axes(("layers",) + tuple(xs[0][1]))),
        *layers, is_leaf=is_leaf)
    return {"encoder": stack(enc), "decoder": stack(dec)}


def encode(params, cfg, frames):
    """frames (B, S_enc, d) stub embeddings -> encoder output (B, S_enc, d)."""
    B, S, d = frames.shape
    x = frames + sinusoid_positions(S, d)[None].astype(frames.dtype)

    def body(x, p):
        h = layer_norm(x, p["norm1"], cfg.norm_eps)
        q, k, v = attention.qkv(p["attn"], cfg, h, None)   # no rope: abs pos
        o = attention.chunked_attention(q, k, v, cfg, causal=False)
        x = x + attention.out_proj(p["attn"], cfg, o)
        h2 = layer_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp.gelu_mlp(p["ffn"], h2)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    from repro.models.scan_utils import maybe_scan
    x, _ = maybe_scan(body, x, params["encoder"], unroll=not cfg.scan_layers)
    return x


def cross_kv(params, cfg, enc_out):
    """Precompute per-layer cross-attention K/V: (L, B, S_enc, K, hd)."""
    def body(_, p):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(enc_out.dtype))
        return None, (k, v)
    _, (ek, ev) = jax.lax.scan(body, None, params["decoder"])
    return ek, ev


def _cross_sub(p, cfg, h, ek, ev):
    q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(h.dtype))
    o = attention.chunked_attention(q, ek, ev, cfg, causal=False,
                                    chunk=min(cfg.attn_chunk, ek.shape[1]))
    return jnp.einsum("bshk,hkd->bsd", o, p["cross"]["wo"].astype(h.dtype))


def decode_train(params, cfg, x, enc_out, positions):
    """Teacher-forced decoder forward.  x (B,S_dec,d) token embeddings."""
    def body(x, p):
        h = layer_norm(x, p["norm1"], cfg.norm_eps)
        q, k, v = attention.qkv(p["attn"], cfg, h, positions)
        o = attention.chunked_attention(q, k, v, cfg, causal=True)
        x = x + attention.out_proj(p["attn"], cfg, o)
        hx = layer_norm(x, p["norm_x"], cfg.norm_eps)
        ek = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(x.dtype))
        ev = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(x.dtype))
        x = x + _cross_sub(p, cfg, hx, ek, ev)
        h2 = layer_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp.gelu_mlp(p["ffn"], h2)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    from repro.models.scan_utils import maybe_scan
    x, _ = maybe_scan(body, x, params["decoder"], unroll=not cfg.scan_layers)
    return x


def init_decode_states(cfg, B, ctx: DecodeCtx, enc_kv, kv_dtype=jnp.bfloat16):
    """Per-decoder-layer states: paged self-KV pools + static cross KV."""
    L = cfg.num_layers
    k_pool, v_pool = paged_kv.init_pool(
        ctx.pool_pages, ctx.page_tokens, cfg.num_kv_heads, cfg.head_dim, kv_dtype)
    ek, ev = enc_kv                                        # (L,B,Se,K,hd)
    return {
        "k_pool": jnp.broadcast_to(k_pool[None], (L,) + k_pool.shape).copy(),
        "v_pool": jnp.broadcast_to(v_pool[None], (L,) + v_pool.shape).copy(),
        "ek": ek, "ev": ev,
    }


def decode_step_stack(params, cfg, x, states, block_table, pos, ctx):
    """One decoder token step.  x (B,1,d)."""
    def body(x, scans):
        p, st = scans
        h = layer_norm(x, p["norm1"], cfg.norm_eps)
        sub, new_kv = _paged_attn_sub(p["attn"], cfg, h, st, block_table, pos, ctx)
        x = x + sub
        hx = layer_norm(x, p["norm_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hx, p["cross"]["wq"].astype(x.dtype))
        o = attention.decode_attention_dense(
            q, st["ek"], st["ev"],
            jnp.full((x.shape[0],), st["ek"].shape[1], jnp.int32),
            cfg.replace(sliding_window=0))
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["cross"]["wo"].astype(x.dtype))
        h2 = layer_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp.gelu_mlp(p["ffn"], h2)
        return x, {**new_kv, "ek": st["ek"], "ev": st["ev"]}

    from repro.models.scan_utils import maybe_scan
    x, new_states = maybe_scan(body, x, (params["decoder"], states),
                               unroll=not cfg.scan_layers)
    return x, new_states
