"""Shared model layers: norms, RoPE, embeddings, param-tree helpers.

Parameter convention: every init function returns a pytree whose leaves are
``(array, logical_axes)`` tuples; ``split_params`` separates them into a
params tree and an axes tree (consumed by distributed/sharding.py).
Logical axis names: batch, seq, embed, heads, kv_heads, head_dim, mlp,
vocab, expert, layers, state, conv, dt_rank.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class Axes(tuple):
    """Logical-axis names as a LEAFLESS pytree node: static metadata that
    survives jax.eval_shape / tracing (strings are not valid JAX leaves)."""


jax.tree_util.register_pytree_node(
    Axes, lambda a: ((), tuple(a)), lambda aux, _: Axes(aux))


def leaf(arr, *axes):
    assert arr.ndim == len(axes), (arr.shape, axes)
    return (arr, Axes(axes))


def is_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], Axes)


def split_params(tree):
    params = jax.tree.map(lambda t: t[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda t: t[1], tree, is_leaf=is_leaf)
    return params, axes


def dense_init(key, fan_in, shape, axes, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return leaf(jax.random.normal(key, shape, dtype) * scale, *axes)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return leaf(jax.random.normal(key, (vocab, d), dtype) * 0.02, "vocab", "embed")


def norm_init(d, centered=False):
    p = {"scale": leaf(jnp.ones((d,), jnp.float32), "embed")}
    if centered:
        p["bias"] = leaf(jnp.zeros((d,), jnp.float32), "embed")
    return p


def rms_norm(x, params, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def layer_norm(x, params, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"]
    if "bias" in params:
        y = y + params["bias"]
    return y.astype(dt)


def head_rms_norm(x, scale, eps=1e-5):
    """QK-norm: RMS over head_dim of (B, S, H, hd)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding, llama 'rotate-half' convention.

    x: (B, S, H, hd) with even hd; positions: (B, S) int32.
    """
    B, S, H, hd = x.shape
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def sinusoid_positions(S, d):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    out = np.zeros((S, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)
