"""Mamba (S6) block: chunked selective scan, jamba's SSM layer.

Training uses a chunked scan: lax.scan over chunks of length cfg.mamba_chunk,
associative_scan (parallel) within each chunk, recurrent state carried across
chunks — the standard memory/parallelism trade for selective SSMs on TPU.
Decode is the exact single-step recurrence with (conv, ssm) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, leaf


def init(key, cfg):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    dtr, cw = cfg.dt_rank, cfg.ssm_conv_width
    ks = jax.random.split(key, 7)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "wx": dense_init(ks[0], d, (d, di), ("embed", "mlp")),
        "wz": dense_init(ks[1], d, (d, di), ("embed", "mlp")),
        "conv_w": dense_init(ks[2], cw, (cw, di), ("conv", "mlp")),
        "conv_b": leaf(jnp.zeros((di,), jnp.float32), "mlp"),
        "x_proj": dense_init(ks[3], di, (di, dtr + 2 * N), ("mlp", "dt_rank")),
        "dt_proj": dense_init(ks[4], dtr, (dtr, di), ("dt_rank", "mlp")),
        "dt_bias": leaf(jnp.full((di,), -4.6, jnp.float32), "mlp"),  # softplus^-1(0.01)
        "A_log": leaf(jnp.log(A), "mlp", "state"),
        "D": leaf(jnp.ones((di,), jnp.float32), "mlp"),
        "out_proj": dense_init(ks[5], di, (di, d), ("mlp", "embed")),
    }


def _ssm_inputs(params, cfg, xc):
    """xc (B,L,di) conv+silu output -> discretized dA, dBx, C."""
    N, dtr = cfg.ssm_state_dim, cfg.dt_rank
    proj = jnp.einsum("bld,dk->blk", xc, params["x_proj"].astype(xc.dtype))
    dt_raw, Bs, Cs = jnp.split(proj.astype(jnp.float32), [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_raw, params["dt_proj"]) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                                   # (di,N)
    dA = jnp.exp(dt[..., None] * A[None, None])                     # (B,L,di,N)
    dBx = dt[..., None] * Bs[:, :, None, :] * xc.astype(jnp.float32)[..., None]
    return dA, dBx, Cs


def _conv(params, cfg, x, conv_state=None):
    """Causal depthwise conv1d, width cw.  x (B,S,di)."""
    cw = cfg.ssm_conv_width
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                          # (B,S+cw-1,di)
    w = params["conv_w"].astype(x.dtype)                            # (cw,di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    out = out + params["conv_b"].astype(x.dtype)
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return out, new_state


def apply(params, cfg, x, *, chunk=None):
    """Training/prefill forward.  x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state_dim
    L = min(chunk or cfg.mamba_chunk, S)
    assert S % L == 0
    nc = S // L
    dt = x.dtype

    xi = jnp.einsum("bsd,de->bse", x, params["wx"].astype(dt))
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(dt))
    xc, _ = _conv(params, cfg, xi)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt)

    xc_c = xc.reshape(B, nc, L, di).transpose(1, 0, 2, 3)           # (nc,B,L,di)

    def chunk_step(h, xck):
        dA, dBx, Cs = _ssm_inputs(params, cfg, xck)                 # (B,L,di,N)
        # associative scan within the chunk: elements (a, b); h_t = a_t h_{t-1} + b_t
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        a_cum, s = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
        hs = a_cum * h[:, None] + s                                 # (B,L,di,N)
        y = jnp.einsum("blds,bls->bld", hs, Cs)                     # (B,L,di)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    from repro.models.scan_utils import maybe_scan
    def chunk_step2(h, xck):
        h2, y = chunk_step(h, xck)
        return h2, y
    _, ys = maybe_scan(chunk_step2, h0, xc_c, unroll=cfg.inner_unroll)  # (nc,B,L,di)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di).astype(jnp.float32)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bse,ed->bsd", y.astype(dt), params["out_proj"].astype(dt))


def init_state(cfg, B, dtype=jnp.float32):
    di, N, cw = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
    return {
        "conv": jnp.zeros((B, cw - 1, di), dtype),
        "ssm": jnp.zeros((B, di, N), jnp.float32),
    }


def decode_step(params, cfg, state, x):
    """x (B,1,d) -> (y (B,1,d), new state).  Exact recurrence."""
    dt = x.dtype
    xi = jnp.einsum("bsd,de->bse", x, params["wx"].astype(dt))
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(dt))
    xc, conv_state = _conv(params, cfg, xi, state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt)             # (B,1,di)
    dA, dBx, Cs = _ssm_inputs(params, cfg, xc)
    h = dA[:, 0] * state["ssm"] + dBx[:, 0]                         # (B,di,N)
    y = jnp.einsum("bds,bs->bd", h, Cs[:, 0])[:, None]              # (B,1,di)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(dt), params["out_proj"].astype(dt))
    return out, {"conv": conv_state, "ssm": h}
