"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_swiglu(key, d, ff):
    ks = jax.random.split(key, 3)
    return {
        "gate": dense_init(ks[0], d, (d, ff), ("embed", "mlp")),
        "up": dense_init(ks[1], d, (d, ff), ("embed", "mlp")),
        "down": dense_init(ks[2], ff, (ff, d), ("mlp", "embed")),
    }


def swiglu(params, x):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("bsf,fd->bsd", h, params["down"].astype(dt))


def init_gelu_mlp(key, d, ff):
    ks = jax.random.split(key, 2)
    return {
        "up": dense_init(ks[0], d, (d, ff), ("embed", "mlp")),
        "down": dense_init(ks[1], ff, (ff, d), ("mlp", "embed")),
    }


def gelu_mlp(params, x):
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, params["up"].astype(dt))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    return jnp.einsum("bsf,fd->bsd", h, params["down"].astype(dt))
