"""Top-level model API: init / forward / loss / decode / input_specs.

Covers all assigned families: dense | moe | hybrid (jamba) | ssm (xlstm) |
vlm (internvl: stub patch embeddings prepended) | encdec (whisper: stub
frame embeddings).  The loss is sequence-chunked cross-entropy so the full
(B, S, vocab) logits tensor is never materialized (200k vocabs).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.models.layers import embed_init, norm_init, rms_norm, split_params

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params_and_axes(cfg, key):
    ks = jax.random.split(key, 4)
    tree = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": norm_init(cfg.d_model, centered=cfg.is_encoder_decoder),
    }
    if not cfg.tie_embeddings:
        tree["head"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model)
    if cfg.is_encoder_decoder:
        tree["stacks"] = encdec.init_stacks(ks[2], cfg)
    else:
        stack, _, _ = transformer.init_stack(ks[2], cfg)
        tree["stacks"] = stack
    params, axes = split_params(tree)
    pdt = DTYPES[cfg.param_dtype]
    params = jax.tree.map(lambda x: x.astype(pdt), params)
    return params, axes


def init_params(cfg, key):
    return init_params_and_axes(cfg, key)[0]


def param_axes(cfg):
    """Axes tree without materializing params (Axes nodes are leafless
    static pytree structure, so eval_shape passes them through)."""
    _, axes = jax.eval_shape(lambda k: init_params_and_axes(cfg, k),
                             jax.random.PRNGKey(0))
    return axes


def count_params(cfg, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    total = 0
    scale = cfg.top_k / cfg.num_experts if cfg.num_experts else 1.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = int(np.prod(leaf.shape))
        names = "/".join(str(p) for p in path)
        if active_only and "ffn_moe" in names and "shared" not in names \
                and "router" not in names:
            n = int(n * scale)
        total += n
    return total


def count_params_analytic(cfg, active_only: bool = False) -> int:
    return count_params(cfg, active_only)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens):
    dt = DTYPES[cfg.dtype]
    return params["embed"][tokens].astype(dt)


def _trunk_inputs(params, cfg, batch):
    """Token/stub-frontend embedding; returns (x (B,S,d), positions (B,S))."""
    if cfg.family == "vlm":
        # stub frontend: precomputed patch embeddings prepended
        pe = batch["patch_embeds"].astype(DTYPES[cfg.dtype])   # (B,P,d)
        xt = _embed(params, cfg, batch["tokens"])              # (B,S-P,d)
        x = jnp.concatenate([pe, xt], axis=1)
    else:
        x = _embed(params, cfg, batch["tokens"])
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def forward(params, cfg, batch, shard_ctx=None):
    """Returns (final hidden (B,S,d), aux dict).  Causal LM trunk."""
    if cfg.is_encoder_decoder:
        frames = batch["frames"].astype(DTYPES[cfg.dtype])
        enc_out = encdec.encode(params["stacks"], cfg, frames)
        xd = _embed(params, cfg, batch["dec_tokens"])
        B, Sd = xd.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32), (B, Sd))
        x = encdec.decode_train(params["stacks"], cfg, xd, enc_out, positions)
        return x, {}
    x, positions = _trunk_inputs(params, cfg, batch)
    x, aux = transformer.apply_stack(params["stacks"], cfg, x, positions,
                                     shard_ctx=shard_ctx)
    return x, aux


def _head(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["head"]


def logits_fn(params, cfg, x):
    """Full logits (small vocabs / decode only)."""
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                      _head(params, cfg).astype(jnp.float32))


def chunked_cross_entropy(params, cfg, x, labels, chunk: int = 512):
    """Sequence-chunked CE: never materializes (B,S,V).  labels -100 = pad."""
    B, S, d = x.shape
    h = rms_norm(x, params["final_norm"], cfg.norm_eps).astype(jnp.float32)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    head = _head(params, cfg).astype(jnp.float32)

    def body(carry, args):
        loss_sum, tok_sum = carry
        hx, lx = args                                   # (B,c,d), (B,c)
        logits = jnp.einsum("bcd,vd->bcv", hx, head)
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = lx >= 0
        lbl = jnp.maximum(lx, 0)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        loss = jnp.where(mask, lse - gold, 0.0)
        return (loss_sum + jnp.sum(loss), tok_sum + jnp.sum(mask)), None

    body = jax.checkpoint(body)
    from repro.models.scan_utils import maybe_scan
    (loss_sum, tok_sum), _ = maybe_scan(
        body, (jnp.float32(0), jnp.float32(0)), (hc, lc),
        unroll=cfg.inner_unroll)
    return loss_sum / jnp.maximum(tok_sum, 1.0)


def loss_fn(params, cfg, batch, shard_ctx=None):
    """Scalar LM loss (+ MoE aux terms).  batch['labels'] -100 = ignored."""
    x, aux = forward(params, cfg, batch, shard_ctx=shard_ctx)
    labels = batch["labels"]
    loss = chunked_cross_entropy(params, cfg, x, labels)
    extra = sum(v for k_, v in aux.items() if k_ in ("moe_aux", "moe_z"))
    metrics = {"ce_loss": loss, **aux}
    return loss + extra, metrics


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def make_decode_ctx(cfg, serve_cfg, B, mesh=None, axis=None):
    """Page-pool geometry + channel topology for a decode batch.

    Grouped layout (core/paged_kv.py): sequences are grouped by their batch
    shard; pages of a sequence spread over the channel axes.  When the batch
    cannot shard (long-context B=1), EVERY mesh axis becomes a channel and
    page_tokens adapts so n_pages == channels (no padding waste).
    Sliding-window archs bound the live horizon to the window (paper
    tombstone eviction).  ``axis`` kept for API compat (ignored; topology is
    derived from the mesh).
    """
    del axis
    pt = serve_cfg.kv_page_tokens
    horizon = serve_cfg.shape.seq_len
    if cfg.sliding_window:
        horizon = min(horizon, cfg.sliding_window + pt)
    if mesh is None:
        n_pages = max(1, (horizon + pt - 1) // pt)
        return transformer.DecodeCtx(page_tokens=pt, n_pages=n_pages,
                                     pool_pages=B * n_pages)
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    d_batch = 1
    for a in baxes:
        d_batch *= mesh.shape[a]
    if B % d_batch == 0 and d_batch > 1:
        batch_axes = baxes
        channel_axes = ("model",)
    else:
        batch_axes = ()
        channel_axes = tuple(mesh.axis_names)
    dm = 1
    for a in channel_axes:
        dm *= mesh.shape[a]
    # adapt page size so every channel holds >=1 page without overallocation
    while pt > 16 and (horizon + pt - 1) // pt < dm:
        pt //= 2
    n_pages = max(1, (horizon + pt - 1) // pt)
    n_pages = ((n_pages + dm - 1) // dm) * dm
    n_shards = d_batch * dm if batch_axes else dm
    pool = B * n_pages
    pool = ((pool + n_shards - 1) // n_shards) * n_shards
    return transformer.DecodeCtx(
        page_tokens=pt, n_pages=n_pages, pool_pages=pool,
        batch_axes=batch_axes, channel_axes=channel_axes,
        pages_per_shard=pool // n_shards, mesh=mesh)


def init_decode_states(params, cfg, B, ctx, kv_dtype=jnp.bfloat16,
                       enc_frames=None):
    if cfg.is_encoder_decoder:
        enc_out = encdec.encode(params["stacks"], cfg,
                                enc_frames.astype(DTYPES[cfg.dtype]))
        enc_kv = encdec.cross_kv(params["stacks"], cfg, enc_out)
        return encdec.init_decode_states(cfg, B, ctx, enc_kv, kv_dtype)
    return transformer.init_decode_states(cfg, B, ctx, kv_dtype)


def decode_step(params, cfg, states, tokens, pos, block_table, ctx):
    """One token for every sequence.  tokens (B,1) -> logits (B,1,V)."""
    x = _embed(params, cfg, tokens)
    if cfg.is_encoder_decoder:
        x, new_states = encdec.decode_step_stack(
            params["stacks"], cfg, x, states, block_table, pos, ctx)
    else:
        x, new_states = transformer.decode_stack(
            params["stacks"], cfg, x, states, block_table, pos, ctx)
    logits = logits_fn(params, cfg, x)
    return logits, new_states


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell
# ---------------------------------------------------------------------------

def input_specs(cfg, shape_cfg, serve_cfg=None, ctx=None):
    """Dry-run input ShapeDtypeStructs (no allocation)."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    i32 = jnp.int32
    dt = DTYPES[cfg.dtype]
    sd = jax.ShapeDtypeStruct
    if shape_cfg.kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            dec_len = min(512, S)
            return {
                "frames": sd((B, S, cfg.d_model), dt),
                "dec_tokens": sd((B, dec_len), i32),
                "labels": sd((B, dec_len), i32),
            }
        if cfg.family == "vlm":
            P_ = cfg.num_prefix_embeds
            return {
                "patch_embeds": sd((B, P_, cfg.d_model), dt),
                "tokens": sd((B, S - P_), i32),
                "labels": sd((B, S), i32),
            }
        return {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
    # decode: one new token against a seq_len KV horizon
    assert ctx is not None
    return {
        "tokens": sd((B, 1), i32),
        "pos": sd((B,), i32),
        "block_table": sd((B, ctx.n_pages), i32),
    }
