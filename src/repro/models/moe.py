"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch is MegaBlocks/MaxText-style: routed (token, expert) pairs are sorted
by expert, positioned within their expert group, capacity-clipped, and
scattered into an (E, C, d) buffer — no (T, E, C) one-hot tensor is ever
materialized (that would be ~4e13 elements for llama4-maverick train_4k).

The HashMem connection (DESIGN.md §3): an expert buffer with capacity C IS a
hash bucket with bounded slots — overflow tokens are dropped exactly like the
paper's over-utilized buckets overflow to extra pages; the aux load-balance
loss plays the paper's §6 'Hash Function' role of evening out bucket load.
A hash-routing mode (router='hash', Roller et al.) uses repro.core.hashing
directly and needs no router params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init
from repro.core.compat import shard_map


def init(key, cfg, layer_ff=None):
    d, E, ff = cfg.d_model, cfg.num_experts, layer_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (d, E), ("embed", "expert")),
        "gate": dense_init(ks[1], d, (E, d, ff), ("expert", "embed", "mlp")),
        "up": dense_init(ks[2], d, (E, d, ff), ("expert", "embed", "mlp")),
        "down": dense_init(ks[3], ff, (E, ff, d), ("expert", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        from repro.models.mlp import init_swiglu
        p["shared"] = init_swiglu(ks[4], d, ff * cfg.num_shared_experts)
    return p


def _capacity(cfg, T):
    return max(int(T * cfg.top_k / cfg.num_experts * cfg.capacity_factor), cfg.top_k)


def apply(params, cfg, x, *, router_mode: str = "learned"):
    """x (B,S,d) -> (y (B,S,d), aux dict with load-balance/z losses)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    if router_mode == "hash":
        # hash routing (Roller et al.): expert = h(token position hash) — uses
        # the paper's hash family; router params unused for selection.
        from repro.core.hashing import murmur3_fmix
        hashed = murmur3_fmix(jnp.arange(T, dtype=jnp.uint32))
        idx = (hashed[:, None] % jnp.uint32(E)).astype(jnp.int32)
        idx = jnp.concatenate(
            [((idx + j) % E) for j in range(k)], axis=1)                # (T,k)
        gates = jnp.full((T, k), 1.0 / k, jnp.float32)
    else:
        gates, idx = jax.lax.top_k(probs, k)                            # (T,k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # --- aux losses (Switch/GShard) ---
    me = jnp.mean(probs, axis=0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * k))
    aux_loss = cfg.aux_loss_coef * E * jnp.sum(me * ce)
    z_loss = cfg.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # --- sort-based dispatch ---
    C = _capacity(cfg, T)
    e_flat = idx.reshape(T * k)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = gates.reshape(T * k)
    order = jnp.argsort(e_flat)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    start = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - start.astype(jnp.int32)
    keep = pos < C
    dst = jnp.where(keep, e_s * C + pos, E * C)                         # OOB drop

    buf = jnp.zeros((E * C, d), x.dtype).at[dst].set(xf[t_s], mode="drop")
    buf = buf.reshape(E, C, d)

    # --- expert computation (SwiGLU), E parallel ---
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, params["gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, params["up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["down"].astype(dt))
    out_buf = out_buf.reshape(E * C, d)

    # --- combine ---
    routed = out_buf[jnp.minimum(dst, E * C - 1)]                       # (T*k, d)
    contrib = routed * (w_s * keep).astype(routed.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[t_s].add(contrib)

    if "shared" in params:
        from repro.models.mlp import swiglu
        y = y + swiglu(params["shared"], xf[None]).reshape(T, d)

    frac_dropped = 1.0 - jnp.sum(keep) / (T * k)
    return y.reshape(B, S, d), {"moe_aux": aux_loss, "moe_z": z_loss,
                                "moe_dropped": frac_dropped}


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map all_to_all) — the optimized path.
#
# Tokens are already (batch x seq)-sharded 256-way by the sequence-parallel
# residual stream; experts live on 'data' rows (E_loc = E / |data|).  Each
# device routes ONLY its local tokens: one all_to_all over 'data' moves every
# routed token exactly once (the GSPMD global-sort baseline moves the full
# token set per model-replica — 16x more wire bytes; see EXPERIMENTS.md
# §Perf).  Expert weights enter the shard_map with their ff dim unsharded,
# so GSPMD all-gathers them over 'model' at the boundary (FSDP-style).
# Capacity is per-shard (standard for distributed MoE).
# ---------------------------------------------------------------------------

def _local_route(params, cfg, xf):
    """Local top-k routing.  xf (T_loc, d) -> gates, idx, aux parts."""
    E, k = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (xf.shape[0] * k))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gates, idx, me, ce, z


def apply_ep(params, cfg, x, mesh, batch_axes=("data",), model_axis="model"):
    """x (B,S,d) globally; runs the dispatch inside shard_map over the whole
    mesh.  Requires E % |data| == 0 and (B*S) % |mesh| == 0."""
    E, k = cfg.num_experts, cfg.top_k
    d = x.shape[-1]
    baxes = tuple(a for a in batch_axes if a in mesh.axis_names)
    # expert-parallel group: largest suffix of the batch axes that divides E
    # (e.g. jamba's 16 experts on a (2,16,16) mesh -> EP over 'data' only,
    # replicated across pods)
    while baxes and E % int(np.prod([mesh.shape[a] for a in baxes])):
        baxes = baxes[1:]
    Dd = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    E_loc = E // Dd
    xspec = tuple(a for a in batch_axes if a in mesh.axis_names)

    def inner(x_loc, router, gate_w, up_w, down_w):
        B_loc, S_loc, _ = x_loc.shape
        T_loc = B_loc * S_loc
        xf = x_loc.reshape(T_loc, d)
        gates, idx, me, ce, z = _local_route({"router": router}, cfg, xf)

        C = max(int(T_loc * k / E * cfg.capacity_factor), 1)
        e_flat = idx.reshape(T_loc * k)
        t_flat = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)
        w_flat = gates.reshape(T_loc * k)
        order = jnp.argsort(e_flat)
        e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
        start = jnp.searchsorted(e_s, e_s, side="left")
        pos = jnp.arange(T_loc * k, dtype=jnp.int32) - start.astype(jnp.int32)
        keep = pos < C
        dst = jnp.where(keep, e_s * C + pos, E * C)
        send = jnp.zeros((E * C, d), x.dtype).at[dst].set(xf[t_s], mode="drop")
        send = send.reshape(Dd, E_loc * C, d)

        # route tokens to expert owners (one hop over the EP axes)
        recv = jax.lax.all_to_all(send, baxes, 0, 0, tiled=False) \
            if baxes else send
        ebatch = recv.reshape(Dd, E_loc, C, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, Dd * C, d)

        dt = x.dtype
        g = jnp.einsum("ecd,edf->ecf", ebatch, gate_w.astype(dt))
        u = jnp.einsum("ecd,edf->ecf", ebatch, up_w.astype(dt))
        hact = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        out = jnp.einsum("ecf,efd->ecd", hact, down_w.astype(dt))

        back = out.reshape(E_loc, Dd, C, d).transpose(1, 0, 2, 3) \
            .reshape(Dd, E_loc * C, d)
        got = (jax.lax.all_to_all(back, baxes, 0, 0, tiled=False)
               if baxes else back).reshape(E * C, d)
        routed = got[jnp.minimum(dst, E * C - 1)]
        contrib = routed * (w_s * keep).astype(routed.dtype)[:, None]
        y = jnp.zeros((T_loc, d), x.dtype).at[t_s].add(contrib)

        all_axes = tuple(mesh.axis_names)
        aux = cfg.aux_loss_coef * E * jnp.sum(
            jax.lax.pmean(me, all_axes) * jax.lax.pmean(ce, all_axes))
        zl = cfg.router_z_coef * jax.lax.pmean(z, all_axes)
        dropped = 1.0 - jax.lax.pmean(jnp.sum(keep) / (T_loc * k), all_axes)
        return y.reshape(B_loc, S_loc, d), aux, zl, dropped

    P_ = jax.sharding.PartitionSpec
    bspec = xspec if xspec else None
    sspec = model_axis if x.shape[1] % mesh.shape[model_axis] == 0 else None
    espec = baxes if baxes else None
    y, aux, zl, dropped = shard_map(
        inner, mesh=mesh,
        in_specs=(P_(bspec, sspec, None),           # x: batch + seq sharded
                  P_(),                             # router (replicated)
                  P_(espec, None, None),            # experts on EP rows,
                  P_(espec, None, None),            # ff gathered over model
                  P_(espec, None, None)),
        out_specs=(P_(bspec, sspec, None), P_(), P_(), P_()),
        check_vma=False,
    )(x, params["router"], params["gate"], params["up"], params["down"])

    if "shared" in params:
        from repro.models.mlp import swiglu
        y = y + swiglu(params["shared"], x)
    return y, {"moe_aux": aux, "moe_z": zl, "moe_dropped": dropped}
