"""maybe_scan: lax.scan that can unroll to straight-line HLO.

XLA's HloCostAnalysis counts a while-loop body exactly ONCE regardless of
trip count (verified empirically — see EXPERIMENTS.md §Roofline method).
The roofline cost probes therefore lower small unrolled variants; production
lowering keeps lax.scan for compile-time/HLO-size sanity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def maybe_scan(body, init, xs, *, unroll: bool, length=None):
    """jax.lax.scan(body, init, xs) | python-loop unrolled equivalent."""
    if not unroll:
        return jax.lax.scan(body, init, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys
