"""Layer-stack assembly: heterogeneous super-block scan.

Hybrid architectures repeat a fixed unit pattern (jamba: 8 layers = 7 mamba +
1 attention, MoE on odd layers; llama4: dense/MoE alternation; xlstm: 1 sLSTM
+ 7 mLSTM).  We scan over stacked *units* (lax.scan keeps the HLO small for
48-layer 400B configs) and unroll the unit's heterogeneous layers in Python.

Decode threads per-layer states through the same scan; attention layers use
the HashMem paged KV cache (core/paged_kv.py), optionally channel-parallel
via shard_map when ``ctx.axis`` is set.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import paged_kv
from repro.models import attention, mamba, mlp, moe, xlstm
from repro.models.layers import norm_init, rms_norm
from repro.core.compat import shard_map


# ---------------------------------------------------------------------------
# Unit structure
# ---------------------------------------------------------------------------

def scan_unit_size(cfg) -> int:
    u = 1
    if cfg.family == "hybrid":
        u = math.lcm(u, cfg.attn_every)
    if cfg.num_experts:
        u = math.lcm(u, cfg.moe_every)
    if cfg.slstm_every:
        u = math.lcm(u, cfg.slstm_every)
    if cfg.d_ff_dense:
        u = math.lcm(u, cfg.moe_every)
    return u


def layer_kind(cfg, i: int) -> str:
    """'attn' | 'mamba' | 'mlstm' | 'slstm' for global layer index i."""
    if cfg.family == "ssm":
        return "slstm" if cfg.is_slstm_layer(i) else "mlstm"
    if cfg.family == "hybrid":
        return "attn" if cfg.is_attn_layer(i) else "mamba"
    return "attn"


def ffn_kind(cfg, i: int) -> Optional[str]:
    """'moe' | 'dense' | None (xlstm blocks have no separate FFN)."""
    if cfg.family == "ssm":
        return None
    return "moe" if cfg.is_moe_layer(i) else "dense"


@dataclass(frozen=True)
class DecodeCtx:
    """Paged-decode context: page pool geometry + channel topology.

    batch_axes: mesh axes the decode batch is sharded over (sequences are
    grouped per shard); channel_axes: mesh axes pages are spread over (the
    paper's memory channels).  Empty batch_axes (long-context B=1) makes
    every mesh axis a channel.  pages_per_shard follows the grouped pool
    layout in core/paged_kv.py.  mesh=None -> single-device gather path.
    """
    page_tokens: int
    n_pages: int          # block-table width (logical pages per sequence)
    pool_pages: int       # physical pool size (global)
    batch_axes: tuple = ()
    channel_axes: tuple = ()
    pages_per_shard: int = 0
    mesh: Optional[object] = None

    @property
    def sharded(self) -> bool:
        return self.mesh is not None and bool(self.channel_axes)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_layer(key, cfg, i: int):
    kind = layer_kind(cfg, i)
    fk = ffn_kind(cfg, i)
    ks = jax.random.split(key, 3)
    p = {"norm1": norm_init(cfg.d_model)}
    if kind == "attn":
        p["attn"] = attention.init(ks[0], cfg)
    elif kind == "mamba":
        p["mamba"] = mamba.init(ks[0], cfg)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = xlstm.init_slstm(ks[0], cfg)
    if fk is not None:
        p["norm2"] = norm_init(cfg.d_model)
        if fk == "moe":
            p["ffn_moe"] = moe.init(ks[1], cfg)
        else:
            ff = cfg.d_ff_dense or cfg.d_ff
            p["ffn"] = mlp.init_swiglu(ks[1], cfg.d_model, ff)
    return p


def init_stack(key, cfg, num_layers: Optional[int] = None):
    """Stacked unit params: every leaf gets a leading (n_units,) axis."""
    L = num_layers or cfg.num_layers
    unit = scan_unit_size(cfg)
    assert L % unit == 0, (L, unit)
    n_units = L // unit
    keys = jax.random.split(key, L).reshape(n_units, unit, -1)
    units = []
    for u in range(n_units):
        unit_p = {f"j{j}": init_layer(keys[u, j], cfg, u * unit + j)
                  for j in range(unit)}
        units.append(unit_p)
    from repro.models.layers import Axes, is_leaf
    stacked = jax.tree.map(
        lambda *xs: (jnp.stack([x[0] for x in xs]), Axes(("layers",) + tuple(xs[0][1]))),
        *units, is_leaf=is_leaf)
    return stacked, n_units, unit


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_layer(p, cfg, i, x, positions, *, causal=True, shard_ctx=None):
    kind = layer_kind(cfg, i)
    fk = ffn_kind(cfg, i)
    aux = {}
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        q, k, v = attention.qkv(p["attn"], cfg, h, positions)
        o = attention.chunked_attention(q, k, v, cfg, causal=causal)
        sub = attention.out_proj(p["attn"], cfg, o)
    elif kind == "mamba":
        sub = mamba.apply(p["mamba"], cfg, h)
    elif kind == "mlstm":
        sub = xlstm.apply_mlstm(p["mlstm"], cfg, h)
    else:
        sub = xlstm.apply_slstm(p["slstm"], cfg, h)
    x = x + sub
    if fk is not None:
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if fk == "moe":
            if cfg.moe_impl == "ep" and shard_ctx is not None:
                y, aux = moe.apply_ep(
                    p["ffn_moe"], cfg, h2, shard_ctx.mesh,
                    batch_axes=("pod", "data"))
            else:
                y, aux = moe.apply(p["ffn_moe"], cfg, h2)
        else:
            y = mlp.swiglu(p["ffn"], h2)
        x = x + y
    return x, aux


def apply_stack(params_stack, cfg, x, positions, *, causal=True,
                shard_ctx=None):
    """x (B,S,d) -> (x, aux_sums).  lax.scan over stacked units."""
    unit = scan_unit_size(cfg)

    def unit_body(carry, unit_params):
        x, aux_sum = carry
        if shard_ctx is not None:
            x = shard_ctx.residual(x)
        for j in range(unit):
            x, aux = _apply_layer(unit_params[f"j{j}"], cfg, j, x, positions,
                                  causal=causal, shard_ctx=shard_ctx)
            for k_, v_ in aux.items():
                aux_sum[k_] = aux_sum.get(k_, 0.0) + v_
        return (x, aux_sum), None

    if cfg.remat:
        unit_body = jax.checkpoint(unit_body)

    aux0 = {}
    if cfg.num_experts and cfg.family != "ssm":
        aux0 = {"moe_aux": jnp.float32(0), "moe_z": jnp.float32(0),
                "moe_dropped": jnp.float32(0)}
    from repro.models.scan_utils import maybe_scan
    (x, aux), _ = maybe_scan(unit_body, (x, aux0), params_stack,
                             unroll=not cfg.scan_layers)
    return x, aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_layer_decode_state(cfg, i: int, B: int, ctx: DecodeCtx,
                            kv_dtype=jnp.bfloat16):
    kind = layer_kind(cfg, i)
    if kind == "attn":
        k_pool, v_pool = paged_kv.init_pool(
            ctx.pool_pages, ctx.page_tokens, cfg.num_kv_heads, cfg.head_dim,
            kv_dtype)
        return {"k_pool": k_pool, "v_pool": v_pool}
    if kind == "mamba":
        return mamba.init_state(cfg, B)
    if kind == "mlstm":
        return xlstm.init_mlstm_state(cfg, B)
    return xlstm.init_slstm_state(cfg, B)


def init_decode_states(cfg, B: int, ctx: DecodeCtx, kv_dtype=jnp.bfloat16,
                       num_layers: Optional[int] = None):
    """Stacked (n_units, ...) decode states matching init_stack layout."""
    L = num_layers or cfg.num_layers
    unit = scan_unit_size(cfg)
    n_units = L // unit
    per_unit = {f"j{j}": init_layer_decode_state(cfg, j, B, ctx, kv_dtype)
                for j in range(unit)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape).copy(), per_unit)


def _paged_attn_sub(p_attn, cfg, h, state, block_table, pos, ctx):
    """Single-token attention sublayer against the paged cache."""
    positions = pos[:, None]                                    # (B,1)
    q, k_new, v_new = attention.qkv(p_attn, cfg, h, positions)
    kd = state["k_pool"].dtype
    k_new, v_new = k_new.astype(kd), v_new.astype(kd)
    if not ctx.sharded:
        k_pool, v_pool = paged_kv.append(
            state["k_pool"], state["v_pool"], block_table, pos, k_new, v_new)
        o = paged_kv.paged_decode_attention(
            q, k_pool, v_pool, block_table, pos, cfg)
    else:
        ba, ca = ctx.batch_axes, ctx.channel_axes
        pps = ctx.pages_per_shard

        def inner(k_pool, v_pool, q, k_new, v_new, block_table, pos):
            k_pool, v_pool = paged_kv.append_sharded(
                k_pool, v_pool, block_table, pos, k_new, v_new, ba, ca, pps)
            o = paged_kv.decode_attention_sharded(
                q, k_pool, v_pool, block_table, pos, cfg, ba, ca, pps)
            return k_pool, v_pool, o

        pool_spec = P(tuple(ba) + tuple(ca))     # grouped page layout
        bspec = P(ba if ba else None)
        k_pool, v_pool, o = shard_map(
            inner, mesh=ctx.mesh,
            in_specs=(pool_spec, pool_spec, bspec, bspec, bspec, bspec, bspec),
            out_specs=(pool_spec, pool_spec, bspec),
            check_vma=False,
        )(state["k_pool"], state["v_pool"], q, k_new, v_new, block_table, pos)
    sub = attention.out_proj(p_attn, cfg, o)
    return sub, {"k_pool": k_pool, "v_pool": v_pool}


def _apply_layer_decode(p, cfg, i, x, state, block_table, pos, ctx):
    kind = layer_kind(cfg, i)
    fk = ffn_kind(cfg, i)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        sub, state = _paged_attn_sub(p["attn"], cfg, h, state, block_table,
                                     pos, ctx)
    elif kind == "mamba":
        sub, state = mamba.decode_step(p["mamba"], cfg, state, h)
    elif kind == "mlstm":
        sub, state = xlstm.decode_mlstm(p["mlstm"], cfg, state, h)
    else:
        sub, state = xlstm.decode_slstm(p["slstm"], cfg, state, h)
    x = x + sub
    if fk is not None:
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if fk == "moe":
            y, _ = moe.apply(p["ffn_moe"], cfg, h2)
        else:
            y = mlp.swiglu(p["ffn"], h2)
        x = x + y
    return x, state


def decode_stack(params_stack, cfg, x, states, block_table, pos, ctx):
    """One decode step through all units.  x (B,1,d)."""
    unit = scan_unit_size(cfg)

    def unit_body(x, scans):
        unit_params, unit_state = scans
        new_state = {}
        for j in range(unit):
            x, s = _apply_layer_decode(unit_params[f"j{j}"], cfg, j, x,
                                       unit_state[f"j{j}"], block_table, pos, ctx)
            new_state[f"j{j}"] = s
        return x, new_state

    from repro.models.scan_utils import maybe_scan
    x, new_states = maybe_scan(unit_body, x, (params_stack, states),
                               unroll=not cfg.scan_layers)
    return x, new_states
