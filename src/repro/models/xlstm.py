"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM.

mLSTM (matrix memory, exponential gating) is trained chunkwise: within a
chunk the output is an attention-like masked product with log-gate decays;
across chunks the (C, n, m) state recurs — the stabilized chunkwise form
(xLSTM paper App. A / TFLA).  The stabilizer m is carried so exp() never
overflows.  sLSTM (scalar memory, block-diagonal recurrence) is inherently
sequential and runs as a lax.scan over time.

States are stored stabilized: C_tilde = C*exp(-m), n_tilde = n*exp(-m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, leaf

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, H, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    assert H * dh == d, "xlstm cell operates at model width (H*hd == d)"
    ks = jax.random.split(key, 8)
    return {
        "wup": dense_init(ks[0], d, (d, 2 * d), ("embed", "mlp")),
        "wq": dense_init(ks[1], d, (d, H, dh), ("embed", "heads", "head_dim")),
        "wk": dense_init(ks[2], d, (d, H, dh), ("embed", "heads", "head_dim")),
        "wv": dense_init(ks[3], d, (d, H, dh), ("embed", "heads", "head_dim")),
        "wi": dense_init(ks[4], d, (d, H), ("embed", "heads")),
        "wf": dense_init(ks[5], d, (d, H), ("embed", "heads")),
        "gn_scale": leaf(jnp.ones((H, dh), jnp.float32), "heads", "head_dim"),
        "wdown": dense_init(ks[6], d, (d, d), ("mlp", "embed")),
    }


def _mlstm_proj(params, cfg, x):
    dt = x.dtype
    H, dh = cfg.num_heads, cfg.head_dim
    up = jnp.einsum("bsd,de->bse", x, params["wup"].astype(dt))
    xm, z = jnp.split(up, 2, axis=-1)                               # (B,S,d) each
    q = jnp.einsum("bsd,dhk->bhsk", xm, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", xm, params["wk"].astype(dt)) * (dh ** -0.5)
    v = jnp.einsum("bsd,dhk->bhsk", xm, params["wv"].astype(dt))
    log_i = jnp.einsum("bsd,dh->bhs", xm.astype(jnp.float32), params["wi"])
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bhs", xm.astype(jnp.float32), params["wf"]))
    return q, k, v, log_i, log_f, z


def _head_norm(h, scale, eps):
    """h (B,H,S,dh): RMS per head."""
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(var + eps) * scale[None, :, None, :]


def _mlstm_chunk(carry, qkvif, cfg):
    """One chunk of the stabilized chunkwise mLSTM.

    carry: (C (B,H,dh,dh), n (B,H,dh), m (B,H)) stabilized states.
    qkvif: q,k,v (B,H,L,dh) fp32; log_i, log_f (B,H,L).
    """
    C, n, m = carry
    q, k, v, log_i, log_f = qkvif
    L = q.shape[2]
    b = jnp.cumsum(log_f, axis=-1)                                  # (B,H,L)
    total = b[..., -1]                                              # (B,H)

    # intra-chunk log decay D[t,s] = b_t - b_s + i_s, s<=t
    D = b[..., :, None] - b[..., None, :] + log_i[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask, D, NEG_INF)

    a = b + m[..., None]                                            # inter log-scale
    m_t = jnp.maximum(jnp.max(D, axis=-1), a)                       # (B,H,L)
    Dexp = jnp.where(mask, jnp.exp(D - m_t[..., None]), 0.0)
    inter = jnp.exp(a - m_t)                                        # (B,H,L)

    qk = jnp.einsum("bhtd,bhsd->bhts", q, k)
    w = Dexp * qk                                                   # (B,H,L,L)
    h_num = jnp.einsum("bhts,bhsd->bhtd", w, v) \
        + inter[..., None] * jnp.einsum("bhtd,bhde->bhte", q, C)
    n_dot = jnp.sum(w, axis=-1) + inter * jnp.einsum("bhtd,bhd->bht", q, n)
    h = h_num / jnp.maximum(jnp.abs(n_dot), jnp.exp(-m_t))[..., None]

    # state update to chunk end
    g = total[..., None] - b + log_i                                # (B,H,L)
    m_new = jnp.maximum(total + m, jnp.max(g, axis=-1))
    scale_old = jnp.exp(total + m - m_new)                          # (B,H)
    wk = jnp.exp(g - m_new[..., None])                              # (B,H,L)
    C_new = scale_old[..., None, None] * C + \
        jnp.einsum("bhl,bhld,bhle->bhde", wk, k, v)
    n_new = scale_old[..., None] * n + jnp.einsum("bhl,bhld->bhd", wk, k)
    return (C_new, n_new, m_new), h


def apply_mlstm(params, cfg, x, *, chunk=None):
    """x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    H, dh = cfg.num_heads, cfg.head_dim
    L = min(chunk or cfg.mlstm_chunk, S)
    assert S % L == 0
    nc = S // L
    dt = x.dtype
    q, k, v, log_i, log_f, z = _mlstm_proj(params, cfg, x)
    f32 = lambda t: t.astype(jnp.float32)
    qc = f32(q).reshape(B, H, nc, L, dh).transpose(2, 0, 1, 3, 4)
    kc = f32(k).reshape(B, H, nc, L, dh).transpose(2, 0, 1, 3, 4)
    vc = f32(v).reshape(B, H, nc, L, dh).transpose(2, 0, 1, 3, 4)
    ic = log_i.reshape(B, H, nc, L).transpose(2, 0, 1, 3)
    fc = log_f.reshape(B, H, nc, L).transpose(2, 0, 1, 3)

    def step(carry, args):
        return _mlstm_chunk(carry, args, cfg)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    from repro.models.scan_utils import maybe_scan
    G = cfg.mlstm_scan_groups
    if G and nc % G == 0 and nc // G > 1 and not cfg.inner_unroll:
        # two-level sqrt-remat: only G outer (C,n,m) states are saved for
        # bwd; inner chunk states are recomputed per group.  Cuts the live
        # bwd state of the (B,H,dh,dh) matrix memory by nc/G.
        gi = nc // G
        regroup = lambda t: t.reshape((G, gi) + t.shape[1:])
        xs = jax.tree.map(regroup, (qc, kc, vc, ic, fc))

        @jax.checkpoint
        def group_step(carry, args):
            return jax.lax.scan(step, carry, args)

        _, hs = jax.lax.scan(group_step, (C0, n0, m0), xs)
        hs = hs.reshape((nc,) + hs.shape[2:])
    else:
        _, hs = maybe_scan(step, (C0, n0, m0), (qc, kc, vc, ic, fc),
                           unroll=cfg.inner_unroll and cfg.mlstm_unroll)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)            # (B,H,S,dh)
    h = _head_norm(h, params["gn_scale"], cfg.norm_eps)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d)
    out = h * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bse,ed->bsd", out.astype(dt), params["wdown"].astype(dt))


def init_mlstm_state(cfg, B):
    H, dh = cfg.num_heads, cfg.head_dim
    return {
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.zeros((B, H), jnp.float32),
    }


def decode_mlstm(params, cfg, state, x):
    """Single-token exact recurrence.  x (B,1,d)."""
    B = x.shape[0]
    dt = x.dtype
    q, k, v, log_i, log_f, z = _mlstm_proj(params, cfg, x)
    q1, k1, v1 = (f32[:, :, 0] for f32 in
                  (q.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32)))                          # (B,H,dh)
    li, lf = log_i[..., 0], log_f[..., 0]                           # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    so = jnp.exp(lf + m - m_new)
    si = jnp.exp(li - m_new)
    C = so[..., None, None] * C + si[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k1, v1)
    n = so[..., None] * n + si[..., None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n)), jnp.exp(-m_new))
    h = (num / den[..., None])[:, :, None]                          # (B,H,1,dh)
    h = _head_norm(h, params["gn_scale"], cfg.norm_eps)
    h = h.transpose(0, 2, 1, 3).reshape(B, 1, -1)
    out = h * jax.nn.silu(z.astype(jnp.float32))
    y = jnp.einsum("bse,ed->bsd", out.astype(dt), params["wdown"].astype(dt))
    return y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d, H, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    ff = ((4 * d // 3) + 63) // 64 * 64
    ks = jax.random.split(key, 7)
    return {
        "wg": dense_init(ks[0], d, (d, 4, H, dh), ("embed", "conv", "heads", "head_dim")),
        "rg": dense_init(ks[1], dh, (4, H, dh, dh), ("conv", "heads", "head_dim", "head_dim")),
        "bg": leaf(jnp.zeros((4, H, dh), jnp.float32), "conv", "heads", "head_dim"),
        "gn_scale": leaf(jnp.ones((H, dh), jnp.float32), "heads", "head_dim"),
        "up1": dense_init(ks[2], d, (d, ff), ("embed", "mlp")),
        "up2": dense_init(ks[3], d, (d, ff), ("embed", "mlp")),
        "down": dense_init(ks[4], ff, (ff, d), ("mlp", "embed")),
    }


def _slstm_cell(params, cfg, carry, gx):
    """carry: (c,n,h,m) each (B,H,dh); gx (B,4,H,dh) input preactivations."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,ghde->bghe", h, params["rg"])
    pre = gx + rec + params["bg"][None]
    i_p, f_p, z_p, o_p = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_f = jax.nn.log_sigmoid(f_p)
    log_i = i_p
    m_new = jnp.maximum(log_f + m, log_i)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * jnp.tanh(z_p)
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
    h_new = jax.nn.sigmoid(o_p) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def apply_slstm(params, cfg, x):
    """x (B,S,d) -> (B,S,d); sequential scan over S (inherently serial)."""
    B, S, d = x.shape
    H, dh = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    gx = jnp.einsum("bsd,dghe->sbghe", x.astype(jnp.float32), params["wg"])

    def step(carry, g):
        new = _slstm_cell(params, cfg, carry, g)
        return new, new[2]

    z0 = jnp.zeros((B, H, dh), jnp.float32)
    init = (z0, z0, z0, jnp.full((B, H, dh), 0.0, jnp.float32))
    _, hs = jax.lax.scan(step, init, gx)                            # (S,B,H,dh)
    h = hs.transpose(1, 0, 2, 3)                                    # (B,S,H,dh)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + cfg.norm_eps) * params["gn_scale"][None, None]
    y = h.reshape(B, S, d).astype(dt)
    # GLU post-MLP (xLSTM sLSTM block)
    u = jnp.einsum("bsd,df->bsf", y, params["up1"].astype(dt))
    g = jnp.einsum("bsd,df->bsf", y, params["up2"].astype(dt))
    u = u * jax.nn.gelu(g.astype(jnp.float32)).astype(dt)
    return jnp.einsum("bsf,fd->bsd", u, params["down"].astype(dt))


def init_slstm_state(cfg, B):
    H, dh = cfg.num_heads, cfg.head_dim
    z = jnp.zeros((B, H, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def decode_slstm(params, cfg, state, x):
    dt = x.dtype
    gx = jnp.einsum("bd,dghe->bghe", x[:, 0].astype(jnp.float32), params["wg"])
    carry = (state["c"], state["n"], state["h"], state["m"])
    c, n, h, m = _slstm_cell(params, cfg, carry, gx)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + cfg.norm_eps) * params["gn_scale"][None]
    y = hn.reshape(x.shape[0], 1, -1).astype(dt)
    u = jnp.einsum("bsd,df->bsf", y, params["up1"].astype(dt))
    g = jnp.einsum("bsd,df->bsf", y, params["up2"].astype(dt))
    u = u * jax.nn.gelu(g.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsf,fd->bsd", u, params["down"].astype(dt))
    return out, {"c": c, "n": n, "h": h, "m": m}
