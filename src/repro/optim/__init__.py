from repro.optim.adamw import init_opt_state, adamw_update, lr_schedule
