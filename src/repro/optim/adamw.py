"""AdamW with global-norm clipping and warmup+cosine schedule.

Optimizer state dtype is configurable: ``state_dtype='bfloat16'`` halves the
m/v memory (the 400B llama4 config needs it to fit 16 GB/chip; the bf16-Adam
regime follows DeepSeek-V2/-V3 practice).  All update math runs in fp32
regardless of storage dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimConfig


def lr_schedule(oc: OptimConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params, oc: OptimConfig):
    dt = jnp.bfloat16 if oc.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms/scales/biases (1-D params)."""
    name = "/".join(str(p) for p in path)
    return "scale" not in name and "bias" not in name and "norm" not in name


def adamw_update(params, grads, state, oc: OptimConfig):
    step = state["step"] + 1
    lr = lr_schedule(oc, step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gn, 1e-9)) \
        if oc.grad_clip else 1.0
    sdt = jnp.bfloat16 if oc.state_dtype == "bfloat16" else jnp.float32
    t = step.astype(jnp.float32)
    bc1 = 1 - oc.b1 ** t
    bc2 = 1 - oc.b2 ** t

    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32) * clip
        m32 = oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * g32
        v32 = oc.b2 * v.astype(jnp.float32) + (1 - oc.b2) * jnp.square(g32)
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + oc.eps)
        if oc.weight_decay and _decay_mask(path):
            upd = upd + oc.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m32.astype(sdt))
        new_v.append(v32.astype(sdt))

    treedef = jax.tree.structure(params)
    unflat = lambda leaves: jax.tree.unflatten(treedef, leaves)
    new_state = {"m": unflat(new_m), "v": unflat(new_v), "step": step}
    return unflat(new_p), new_state, {"grad_norm": gn, "lr": lr}
