"""Multi-tenant continuous-batching serving layer over HashMem.

  engine.py   — ServingEngine / SlotPool / Request: admission control,
                slot lifecycle, step-level op coalescing (one vectorized
                HashMem call per phase per shard per tick)
  tenancy.py  — tenant-folded key space, quotas, per-tenant stats
  metrics.py  — bounded log-bucketed histograms, hot-key sketch,
                per-phase latency, Prometheus exposition
  tracing.py  — tick-level spans on a bounded ring, Chrome/Perfetto
                trace-event export (``ServingEngine(trace=True)``)
  loadgen.py  — YCSB-style workloads A-F (zipfian / uniform / latest)
"""
from repro.serving.engine import (   # noqa: F401
    PAD_KEY, Request, ServingEngine, SlotPool,
)
from repro.serving.loadgen import (  # noqa: F401
    LoadGen, WorkloadSpec, build_ycsb_engine, preload_engine,
)
from repro.serving.metrics import LogHistogram, MetricsCollector, SpaceSaving  # noqa: F401
from repro.serving.tracing import NULL_TRACER, Tracer  # noqa: F401
from repro.serving.tenancy import Tenant, TenantRegistry, TenantSpace  # noqa: F401
