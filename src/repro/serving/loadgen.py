"""YCSB-style load generator for the serving engine.

Builds :class:`repro.serving.engine.Request` streams from the YCSB core
workloads (A update-heavy, B read-mostly, C read-only, D read-latest,
E short-scans, F read-modify-write) with Zipfian / uniform / latest key
choice, on top of the shared generators in ``repro.data.kv_synth``
(``ycsb_mix`` / ``zipfian_weights``).  Each request is a short session of
``ops_per_request`` ops, so continuous batching has multi-tick lifetimes to
schedule around.

The load phase (`preload`) inserts ``record_count`` keys 0..N-1; the run
phase draws op keys from the loaded range, extending it on "insert" ops
(the YCSB insertion-point counter), which is what the "latest" distribution
skews toward.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.kv_synth import ycsb_default_dist, ycsb_mix, zipfian_weights
from repro.serving.engine import Request
from repro.serving.tenancy import Tenant

DISTRIBUTIONS = ("zipfian", "uniform", "latest")


@dataclass
class WorkloadSpec:
    """One tenant's workload: a YCSB mix (or explicit op probabilities)
    over a bounded key range."""
    workload: str = "A"                 # YCSB core workload id
    record_count: int = 1024            # preloaded keys 0..record_count-1
    ops_per_request: int = 4
    distribution: str = ""              # "" -> the workload's YCSB default
    theta: float = 0.99                 # zipfian skew constant
    scan_len: int = 8                   # max scan length (E)
    mix: dict | None = None             # overrides ycsb_mix(workload)

    def resolved_mix(self) -> dict:
        return dict(self.mix) if self.mix else ycsb_mix(self.workload)

    def resolved_dist(self) -> str:
        d = self.distribution or ycsb_default_dist(self.workload)
        assert d in DISTRIBUTIONS, d
        return d


class LoadGen:
    """Request-stream generator for one (tenant, workload) pair."""

    def __init__(self, spec: WorkloadSpec, tenant: Tenant | None = None,
                 seed: int = 0):
        self.spec = spec
        self.tenant = tenant
        self.rng = np.random.default_rng(seed)
        self.mix = spec.resolved_mix()
        self.dist = spec.resolved_dist()
        self.kinds = list(self.mix)
        self.probs = np.asarray([self.mix[k] for k in self.kinds])
        self.probs = self.probs / self.probs.sum()
        self.insert_point = spec.record_count    # YCSB insertion counter
        self._zipf_n = 0
        self._zipf_w = None

    # -- key choice --------------------------------------------------------
    def _zipf(self, n: int) -> int:
        """Zipfian rank in [0, n).  The O(n) weight vector is rebuilt only
        when the key range has grown ~25% past the cached size (inserts bump
        ``insert_point`` on every op in insert-bearing workloads); between
        rebuilds ranks are drawn over the cached prefix — the hot head,
        which is where a zipfian draw lands anyway."""
        if self._zipf_w is None or n < self._zipf_n or n > self._zipf_n * 1.25:
            self._zipf_n = n
            self._zipf_w = zipfian_weights(n, self.spec.theta)
        return min(int(self.rng.choice(self._zipf_n, p=self._zipf_w)), n - 1)

    def choose_key(self) -> int:
        n = max(self.insert_point, 1)
        if self.dist == "uniform":
            return int(self.rng.integers(0, n))
        if self.dist == "latest":
            # skew toward the most recently inserted keys: zipfian over
            # recency rank (YCSB's LatestGenerator)
            return (n - 1) - self._zipf(n)
        return self._zipf(n)

    def next_insert_key(self) -> int:
        k = self.insert_point
        self.insert_point += 1
        return k

    # -- ops / requests ----------------------------------------------------
    def next_op(self) -> tuple:
        kind = self.kinds[int(self.rng.choice(len(self.kinds), p=self.probs))]
        val = int(self.rng.integers(1, 2**31))
        if kind == "read":
            return ("read", self.choose_key())
        if kind == "update":
            return ("update", self.choose_key(), val)
        if kind == "insert":
            return ("insert", self.next_insert_key(), val)
        if kind == "scan":
            n = int(self.rng.integers(1, self.spec.scan_len + 1))
            return ("scan", self.choose_key(), n)
        if kind == "rmw":
            return ("rmw", self.choose_key(), val)
        raise ValueError(kind)

    def request(self) -> Request:
        ops = [self.next_op() for _ in range(self.spec.ops_per_request)]
        return Request(ops=ops, tenant=self.tenant)

    def requests(self, n: int) -> list:
        return [self.request() for _ in range(n)]

    # -- load phase --------------------------------------------------------
    def preload_kv(self, seed: int | None = None):
        """(keys, vals) for the YCSB load phase: keys 0..record_count-1."""
        rng = np.random.default_rng(self.rng.integers(2**31)
                                    if seed is None else seed)
        keys = np.arange(self.spec.record_count, dtype=np.uint32)
        vals = rng.integers(1, 2**31, self.spec.record_count,
                            dtype=np.int64).astype(np.uint32)
        return keys, vals


def preload_engine(engine, gens: list) -> None:
    """Run the load phase for every generator into the engine's shards."""
    for g in gens:
        keys, vals = g.preload_kv()
        engine.preload(keys, vals, tenant=g.tenant)


def build_ycsb_engine(workloads, *, slots=16, shards=1, record_count=1024,
                      ops_per_request=4, coalesce=True, backend="ref",
                      seed=0, max_pending=0, tenant_slots=0, metrics=None,
                      cfg=None, mesh=None, pipeline_depth=1,
                      fused_tick=None, trace=None):
    """One preloaded engine + one (tenant, LoadGen) per YCSB workload letter
    — the single assembly path shared by the serve.py kv CLI and
    benchmarks/serving_bench.py, so both exercise identically-sized tables.
    ``mesh``: route the shards through the RLU mesh path (one stacked table,
    one shard per device on the 'model' axis; ``shards`` is ignored).
    ``pipeline_depth``: multi-tick op pipelining (engine.py).
    ``fused_tick``: None = engine default (fused whole-tick megakernel on
    mesh+coalesce), False = per-phase shard_map calls.
    Returns (engine, [LoadGen, ...])."""
    from repro.configs.base import HashMemConfig
    from repro.serving.engine import ServingEngine
    from repro.serving.tenancy import TenantRegistry

    reg = TenantRegistry()
    gens = []
    for i, wl in enumerate(workloads):
        t = reg.register(f"tenant{i}-{wl}", max_slots=tenant_slots)
        gens.append(LoadGen(WorkloadSpec(wl, record_count=record_count,
                                         ops_per_request=ops_per_request),
                            t, seed=seed + i))
    cfg = cfg or HashMemConfig(num_buckets=max(256, record_count // 16),
                               slots_per_page=64,
                               overflow_pages=max(256, record_count // 16),
                               max_chain=8, backend=backend)
    eng = ServingEngine(cfg, num_shards=shards, max_slots=slots,
                        max_pending=max_pending, tenants=reg,
                        metrics=metrics, coalesce=coalesce, mesh=mesh,
                        pipeline_depth=pipeline_depth, fused_tick=fused_tick,
                        trace=trace)
    preload_engine(eng, gens)
    return eng, gens
