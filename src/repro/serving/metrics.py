"""Serving telemetry on bounded state: log-bucketed histograms, a top-K
hot-key sketch, per-phase latency blocks, and Prometheus exposition.

Everything is host-side, allocation-light, and — unlike the earlier
list-accumulating collector, which grew ``req_ticks``/``req_secs``/
``tick_ops`` without bound (an OOM on long serving runs) — **O(1) in run
length**: samples land in fixed-size :class:`LogHistogram` buckets
(HdrHistogram-style: exact below ``2*subbuckets`` units, <=
``1/(2*subbuckets)`` relative error above, so percentiles stay within ~1%
of exact at the default 64 sub-buckets), counts and occupancy in exact
running counters, chain telemetry in a bounded ring, and per-key op
frequencies in a :class:`SpaceSaving` top-K sketch (the classic
space-saving counter: every reported count overestimates by at most the
tracked ``err``, and any key with true frequency above ``count_min`` is
guaranteed present — the right shape for skew/hot-key diagnosis).

``snapshot()`` keeps the historical schema (latency/tick/occupancy/op
blocks, chain + rows-activated telemetry) and adds per-phase latency
blocks (fed by the engine's tracer spans via ``record_phase``), the
queue-vs-service split, and the hot-key table; ``to_prom()`` renders the
same state as Prometheus text exposition (counters, gauges, and summary
quantiles) for scraping.  Chain-length telemetry is sampled from the live
HashMem on a throttle, since ``hashmap.stats`` is a device walk + host
sync.
"""
from __future__ import annotations

import json
import math
import time

import numpy as np


def finite(x, default: float = 0.0) -> float:
    """float(x), with NaN/inf coerced to ``default`` — every scalar that can
    land in a BENCH_*.json row goes through here, so a drained-early engine
    (zero completed requests, zero ticks) can never leak NaN or Infinity
    into the trajectory files (Infinity isn't even valid JSON)."""
    x = float(x)
    return x if math.isfinite(x) else default


def percentile(samples, q: float) -> float:
    """q in [0, 100].  Total over the degenerate sample sets a serving run
    can produce: an EMPTY set (engine drained before any request completed)
    returns 0.0 instead of raising like ``np.percentile``, a single sample
    returns that sample for every q, and a non-finite result (NaN samples)
    is coerced to 0.0."""
    if not len(samples):
        return 0.0
    return finite(np.percentile(np.asarray(samples, np.float64), q))


class LogHistogram:
    """Bounded log-bucketed histogram over non-negative floats.

    Values are scaled to integer units of ``lsb`` and bucketed
    HdrHistogram-style: units below ``2*subbuckets`` get their own
    unit-wide bucket (EXACT — integer-valued series like latency-in-ticks
    never see quantization there), larger values land in octaves split
    into ``subbuckets`` linear sub-buckets, so the relative quantization
    error is at most ``1/(2*subbuckets)`` everywhere.  State is one fixed
    int64 count array plus exact count/sum/min/max — O(1) memory however
    many samples are recorded.  ``percentile()`` returns the bucket
    midpoint clamped into [min, max], which makes single-sample and
    constant series exact for every q.
    """

    _MAX_BITS = 52                     # unit magnitudes up to 2^52

    def __init__(self, lsb: float = 1.0, subbuckets: int = 64):
        assert subbuckets >= 2 and subbuckets & (subbuckets - 1) == 0, \
            "subbuckets must be a power of two"
        self.lsb = float(lsb)
        self.S = subbuckets
        self._s = subbuckets.bit_length() - 1
        self.counts = np.zeros((self._MAX_BITS - self._s + 2) * subbuckets,
                               np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _index(self, units: int) -> int:
        if units < 2 * self.S:
            return units
        e = units.bit_length() - 1
        return (e - self._s + 1) * self.S + ((units >> (e - self._s)) - self.S)

    def record(self, value: float, n: int = 1):
        v = float(value)
        if not math.isfinite(v) or v < 0.0:
            v = 0.0
        units = min(int(v / self.lsb), (1 << self._MAX_BITS) - 1)
        self.counts[self._index(units)] += n
        self.count += n
        self.total += v * n
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def _bucket_mid(self, idx: int) -> float:
        if idx < 2 * self.S:
            return idx * self.lsb      # unit-wide bucket: the value itself
        m = idx // self.S
        lo = (self.S + idx % self.S) << (m - 1)
        width = 1 << (m - 1)
        return (lo + width / 2.0) * self.lsb

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        rank = max(1, int(math.ceil(q / 100.0 * self.count)))
        nz = np.nonzero(self.counts)[0]
        cum = np.cumsum(self.counts[nz])
        idx = int(nz[int(np.searchsorted(cum, rank))])
        return finite(min(max(self._bucket_mid(idx), self.vmin), self.vmax))

    def mean(self) -> float:
        return finite(self.total / self.count) if self.count else 0.0

    def min(self) -> float:
        return self.vmin if self.count else 0.0

    def max(self) -> float:
        return self.vmax if self.count else 0.0

    def quantiles(self, scale: float = 1.0) -> dict:
        return {"p50": self.percentile(50) * scale,
                "p99": self.percentile(99) * scale}


class SpaceSaving:
    """Space-saving top-K frequency sketch (Metwally et al.).

    Tracks at most ``k`` keys; a new key evicts the current minimum and
    inherits its count as the overestimation ``err``.  Guarantees: every
    reported count is ``true <= count <= true + err``, and any key whose
    true frequency exceeds the smallest tracked count is in the sketch —
    exactly what's needed to name the hot keys under zipfian skew without
    per-key state.
    """

    def __init__(self, k: int = 64):
        assert k >= 1
        self.k = k
        self._counts: dict = {}          # key -> [count, err]

    def offer(self, key, n: int = 1):
        c = self._counts.get(key)
        if c is not None:
            c[0] += n
        elif len(self._counts) < self.k:
            self._counts[key] = [n, 0]
        else:
            mkey = min(self._counts, key=lambda x: self._counts[x][0])
            mcount = self._counts.pop(mkey)[0]
            self._counts[key] = [mcount + n, mcount]

    def top(self, n: int = 16) -> list:
        """[(key, count, err)] sorted by count descending."""
        items = sorted(self._counts.items(), key=lambda kv: -kv[1][0])
        return [(k, c, e) for k, (c, e) in items[:n]]

    def __len__(self) -> int:
        return len(self._counts)


# the closed op-kind vocabulary: record_ops() rejects anything else, so a
# typo'd kind can't mint a phantom counter key that pollutes BENCH rows
OP_KINDS = ("read", "update", "insert", "delete", "scan", "rmw")

_CHAIN_WINDOW = 64                     # chain-sample ring bound


class MetricsCollector:
    """Per-engine telemetry sink (bounded; see module docstring).

    * ``record_request(ticks, seconds, queue_secs=, service_secs=)`` —
      request completion latency in engine ticks and wall seconds, plus
      the submit→admit (queue) vs admit→complete (service) split;
    * ``record_tick(ops, occupancy, seconds)`` — per-tick throughput and
      slot occupancy;
    * ``record_ops(kind, n, hits)`` — op counts and probe hit rates
      (``kind`` must be one of :data:`OP_KINDS`: ValueError otherwise);
    * ``record_phase(name, seconds)`` — per-phase latency (gather / route /
      fused_tick / writeback / ... — fed from the engine's tracer spans);
    * ``record_hot_keys(keys)`` — folded keys into the top-K sketch;
    * ``sample_chains(hm)`` — chain-length telemetry from a HashMem.
    """

    def __init__(self, chain_sample_every: int = 32, subbuckets: int = 64,
                 hot_k: int = 64):
        self.t0 = time.perf_counter()
        self.ticks = 0
        self.total_ops = 0
        self.occupancy_sum = 0
        self.occupancy_max = 0
        self.requests_completed = 0
        S = subbuckets
        self.req_ticks_h = LogHistogram(1.0, S)
        self.req_secs_h = LogHistogram(1e-6, S)
        self.queue_secs_h = LogHistogram(1e-6, S)
        self.service_secs_h = LogHistogram(1e-6, S)
        self.tick_ops_h = LogHistogram(1.0, S)
        self.tick_secs_h = LogHistogram(1e-6, S)
        self.rows_h = LogHistogram(1.0 / 1024, S)
        self.phase_h: dict[str, LogHistogram] = {}
        self._subbuckets = S
        self.ops = {k: 0 for k in OP_KINDS}
        self.hits = 0
        self.probes = 0
        self.hot = SpaceSaving(hot_k)
        self.chain_sample_every = chain_sample_every
        self._ticks_since_chain_sample = 0
        from collections import deque
        self.chain_samples: deque = deque(maxlen=_CHAIN_WINDOW)

    # -- recording ---------------------------------------------------------
    def record_request(self, ticks: int, seconds: float,
                       queue_secs: float | None = None,
                       service_secs: float | None = None):
        self.requests_completed += 1
        self.req_ticks_h.record(ticks)
        self.req_secs_h.record(seconds)
        if queue_secs is not None:
            self.queue_secs_h.record(queue_secs)
        if service_secs is not None:
            self.service_secs_h.record(service_secs)

    def record_tick(self, ops: int, occupancy: int, seconds: float):
        self.ticks += 1
        self.total_ops += int(ops)
        self.occupancy_sum += int(occupancy)
        if occupancy > self.occupancy_max:
            self.occupancy_max = int(occupancy)
        self.tick_ops_h.record(ops)
        self.tick_secs_h.record(seconds)

    def record_ops(self, kind: str, n: int, hits: int | None = None):
        if kind not in self.ops:
            raise ValueError(
                f"unknown op kind {kind!r} (must be one of {OP_KINDS})")
        self.ops[kind] += n
        if hits is not None:
            self.probes += n
            self.hits += hits

    def record_phase(self, name: str, seconds: float):
        h = self.phase_h.get(name)
        if h is None:
            h = self.phase_h[name] = LogHistogram(1e-6, self._subbuckets)
        h.record(seconds)

    def record_hot_keys(self, keys):
        for k in keys:
            self.hot.offer(int(k))

    def sample_chains(self, hms) -> bool:
        """Throttled chain-length sample over one HashMem, a list of shards
        (aggregated, so a single hot shard is visible in max_chain), or a
        zero-arg callable producing either — the mesh-backed engine passes a
        callable so shard views are only materialized on sampled ticks;
        returns True when it sampled."""
        self._ticks_since_chain_sample += 1
        if self._ticks_since_chain_sample < self.chain_sample_every:
            return False
        self._ticks_since_chain_sample = 0
        self.force_chain_sample(hms)
        return True

    def force_chain_sample(self, hms):
        from repro.core import hashmap
        if callable(hms):
            hms = hms()
        if not isinstance(hms, (list, tuple)):
            hms = [hms]
        cls = [np.asarray(hashmap.chain_lengths(hm)) for hm in hms]
        cl = np.concatenate(cls)
        self.chain_samples.append({
            "tick": self.ticks,
            "mean_chain": float(cl.mean()),
            "max_chain": int(cl.max(initial=0)),
            "chain_p50": percentile(cl, 50),
            "chain_p99": percentile(cl, 99),
            "max_chain_per_shard": [int(c.max(initial=0)) for c in cls],
            "buckets": int(cl.shape[0]),
        })

    def record_rows_activated(self, mean_rows: float):
        """Per-sample mean DRAM-row activations per probe, from
        ``hashmap.rows_activated_per_probe`` on a sampled tick's probe keys
        (the engine throttles this alongside ``sample_chains``)."""
        self.rows_h.record(finite(mean_rows))

    # -- reduction ---------------------------------------------------------
    def snapshot(self) -> dict:
        wall = time.perf_counter() - self.t0
        ticks = self.ticks
        total_ops = self.total_ops
        return {
            "wall_seconds": finite(wall),
            "ticks": ticks,
            "total_ops": total_ops,
            "ops_per_sec": finite(total_ops / wall) if wall > 0 else 0.0,
            "ops_per_tick": finite(total_ops / ticks) if ticks else 0.0,
            "requests_completed": self.requests_completed,
            "request_latency_ticks": {
                "p50": self.req_ticks_h.percentile(50),
                "p99": self.req_ticks_h.percentile(99),
                "max": finite(self.req_ticks_h.max()),
            },
            "request_latency_ms": self.req_secs_h.quantiles(1e3),
            "queue_ms": self.queue_secs_h.quantiles(1e3),
            "service_ms": self.service_secs_h.quantiles(1e3),
            "tick_ms": self.tick_secs_h.quantiles(1e3),
            "phase_ms": {
                name: {**h.quantiles(1e3), "mean": h.mean() * 1e3,
                       "count": h.count}
                for name, h in sorted(self.phase_h.items())},
            "occupancy": {
                "mean": finite(self.occupancy_sum / ticks) if ticks else 0.0,
                "max": self.occupancy_max,
            },
            "op_counts": dict(self.ops),
            "probe_hit_rate": finite(self.hits / self.probes)
            if self.probes else 0.0,
            "hot_keys": [{"key": k, "count": c, "err": e}
                         for k, c, e in self.hot.top(8)],
            "chain_telemetry": list(self.chain_samples)[-8:],
            "chain_depth": {
                "p50": self.chain_samples[-1]["chain_p50"]
                if self.chain_samples else 0.0,
                "p99": self.chain_samples[-1]["chain_p99"]
                if self.chain_samples else 0.0,
            },
            "rows_activated": {
                "p50": self.rows_h.percentile(50),
                "p99": self.rows_h.percentile(99),
                "mean": self.rows_h.mean(),
            },
        }

    def to_json(self, **extra) -> str:
        # allow_nan=False turns any non-finite scalar that slipped past the
        # finite() coercions into a hard error instead of invalid JSON
        return json.dumps({**self.snapshot(), **extra}, indent=2,
                          allow_nan=False)

    def to_prom(self, prefix: str = "hashmem") -> str:
        """Prometheus text exposition of the collector state: op/tick/
        request counters, occupancy gauges, summary quantiles for the
        latency histograms (request/queue/service/tick and every recorded
        phase), and the hot-key table."""
        snap = self.snapshot()
        lines: list[str] = []

        def counter(name, value, labels=""):
            lines.append(f"# TYPE {prefix}_{name} counter")
            lines.append(f"{prefix}_{name}{labels} {value}")

        def gauge(name, value, labels="", typed=True):
            if typed:
                lines.append(f"# TYPE {prefix}_{name} gauge")
            lines.append(f"{prefix}_{name}{labels} {finite(value)}")

        def summary(name, h: LogHistogram):
            lines.append(f"# TYPE {prefix}_{name} summary")
            for q in (0.5, 0.9, 0.99):
                lines.append(f'{prefix}_{name}{{quantile="{q}"}} '
                             f"{finite(h.percentile(q * 100))}")
            lines.append(f"{prefix}_{name}_sum {finite(h.total)}")
            lines.append(f"{prefix}_{name}_count {h.count}")

        counter("ticks_total", snap["ticks"])
        counter("ops_total", snap["total_ops"])
        lines.append(f"# TYPE {prefix}_ops_by_kind_total counter")
        for kind, n in snap["op_counts"].items():
            lines.append(f'{prefix}_ops_by_kind_total{{kind="{kind}"}} {n}')
        counter("requests_completed_total", snap["requests_completed"])
        gauge("ops_per_sec", snap["ops_per_sec"])
        gauge("probe_hit_rate", snap["probe_hit_rate"])
        gauge("occupancy_mean", snap["occupancy"]["mean"])
        gauge("occupancy_max", snap["occupancy"]["max"])
        gauge("chain_depth_p99", snap["chain_depth"]["p99"])
        gauge("rows_activated_mean", snap["rows_activated"]["mean"])
        summary("request_latency_seconds", self.req_secs_h)
        summary("request_queue_seconds", self.queue_secs_h)
        summary("request_service_seconds", self.service_secs_h)
        summary("tick_seconds", self.tick_secs_h)
        lines.append(f"# TYPE {prefix}_phase_seconds summary")
        for name, h in sorted(self.phase_h.items()):
            for q in (0.5, 0.99):
                lines.append(
                    f'{prefix}_phase_seconds{{phase="{name}",'
                    f'quantile="{q}"}} {finite(h.percentile(q * 100))}')
            lines.append(f'{prefix}_phase_seconds_sum{{phase="{name}"}} '
                         f"{finite(h.total)}")
            lines.append(f'{prefix}_phase_seconds_count{{phase="{name}"}} '
                         f"{h.count}")
        lines.append(f"# TYPE {prefix}_hot_key_ops gauge")
        for k, c, _ in self.hot.top(8):
            lines.append(f'{prefix}_hot_key_ops{{key="{k:#x}"}} {c}')
        return "\n".join(lines) + "\n"
