"""Serving telemetry: latency percentiles, throughput, occupancy, chains.

Everything is host-side and allocation-light: samples accumulate in plain
Python lists / counters per tick and are reduced only in ``snapshot()``.
Chain-length telemetry (the per-probe RLU command depth — the quantity the
paper's overflow-chaining design trades space against) is sampled from the
live HashMem on a throttle, since ``hashmap.stats`` is a device walk +
host sync.
"""
from __future__ import annotations

import json
import math
import time

import numpy as np


def finite(x, default: float = 0.0) -> float:
    """float(x), with NaN/inf coerced to ``default`` — every scalar that can
    land in a BENCH_*.json row goes through here, so a drained-early engine
    (zero completed requests, zero ticks) can never leak NaN or Infinity
    into the trajectory files (Infinity isn't even valid JSON)."""
    x = float(x)
    return x if math.isfinite(x) else default


def percentile(samples, q: float) -> float:
    """q in [0, 100].  Total over the degenerate sample sets a serving run
    can produce: an EMPTY set (engine drained before any request completed)
    returns 0.0 instead of raising like ``np.percentile``, a single sample
    returns that sample for every q, and a non-finite result (NaN samples)
    is coerced to 0.0."""
    if not len(samples):
        return 0.0
    return finite(np.percentile(np.asarray(samples, np.float64), q))


class MetricsCollector:
    """Per-engine telemetry sink.

    * ``record_request(ticks, seconds)`` — request completion latency, both
      in engine ticks (scheduling depth) and wall seconds;
    * ``record_tick(ops, occupancy, seconds)`` — per-tick throughput and
      slot occupancy;
    * ``record_ops(kind, n, hits)`` — op counts and probe hit rates;
    * ``sample_chains(hm)`` — chain-length telemetry from a HashMem.
    """

    def __init__(self, chain_sample_every: int = 32):
        self.t0 = time.perf_counter()
        self.req_ticks: list[int] = []
        self.req_secs: list[float] = []
        self.tick_ops: list[int] = []
        self.tick_secs: list[float] = []
        self.occupancy: list[int] = []
        self.ops = {k: 0 for k in
                    ("read", "update", "insert", "delete", "scan", "rmw")}
        self.hits = 0
        self.probes = 0
        self.chain_sample_every = chain_sample_every
        self._ticks_since_chain_sample = 0
        self.chain_samples: list[dict] = []
        self.rows_activated: list[float] = []

    # -- recording ---------------------------------------------------------
    def record_request(self, ticks: int, seconds: float):
        self.req_ticks.append(ticks)
        self.req_secs.append(seconds)

    def record_tick(self, ops: int, occupancy: int, seconds: float):
        self.tick_ops.append(ops)
        self.occupancy.append(occupancy)
        self.tick_secs.append(seconds)

    def record_ops(self, kind: str, n: int, hits: int | None = None):
        self.ops[kind] = self.ops.get(kind, 0) + n
        if hits is not None:
            self.probes += n
            self.hits += hits

    def sample_chains(self, hms) -> bool:
        """Throttled chain-length sample over one HashMem, a list of shards
        (aggregated, so a single hot shard is visible in max_chain), or a
        zero-arg callable producing either — the mesh-backed engine passes a
        callable so shard views are only materialized on sampled ticks;
        returns True when it sampled."""
        self._ticks_since_chain_sample += 1
        if self._ticks_since_chain_sample < self.chain_sample_every:
            return False
        self._ticks_since_chain_sample = 0
        self.force_chain_sample(hms)
        return True

    def force_chain_sample(self, hms):
        from repro.core import hashmap
        if callable(hms):
            hms = hms()
        if not isinstance(hms, (list, tuple)):
            hms = [hms]
        cls = [np.asarray(hashmap.chain_lengths(hm)) for hm in hms]
        cl = np.concatenate(cls)
        self.chain_samples.append({
            "tick": len(self.tick_ops),
            "mean_chain": float(cl.mean()),
            "max_chain": int(cl.max(initial=0)),
            "chain_p50": percentile(cl, 50),
            "chain_p99": percentile(cl, 99),
            "max_chain_per_shard": [int(c.max(initial=0)) for c in cls],
            "buckets": int(cl.shape[0]),
        })

    def record_rows_activated(self, mean_rows: float):
        """Per-sample mean DRAM-row activations per probe, from
        ``hashmap.rows_activated_per_probe`` on a sampled tick's probe keys
        (the engine throttles this alongside ``sample_chains``)."""
        self.rows_activated.append(finite(mean_rows))

    # -- reduction ---------------------------------------------------------
    def snapshot(self) -> dict:
        wall = time.perf_counter() - self.t0
        total_ops = int(sum(self.tick_ops))
        ticks = len(self.tick_ops)
        return {
            "wall_seconds": finite(wall),
            "ticks": ticks,
            "total_ops": total_ops,
            "ops_per_sec": finite(total_ops / wall) if wall > 0 else 0.0,
            "ops_per_tick": finite(total_ops / ticks) if ticks else 0.0,
            "requests_completed": len(self.req_ticks),
            "request_latency_ticks": {
                "p50": percentile(self.req_ticks, 50),
                "p99": percentile(self.req_ticks, 99),
                "max": finite(max(self.req_ticks, default=0)),
            },
            "request_latency_ms": {
                "p50": percentile(self.req_secs, 50) * 1e3,
                "p99": percentile(self.req_secs, 99) * 1e3,
            },
            "tick_ms": {
                "p50": percentile(self.tick_secs, 50) * 1e3,
                "p99": percentile(self.tick_secs, 99) * 1e3,
            },
            "occupancy": {
                "mean": finite(np.mean(self.occupancy)) if self.occupancy
                else 0.0,
                "max": int(max(self.occupancy, default=0)),
            },
            "op_counts": dict(self.ops),
            "probe_hit_rate": finite(self.hits / self.probes)
            if self.probes else 0.0,
            "chain_telemetry": self.chain_samples[-8:],
            "chain_depth": {
                "p50": self.chain_samples[-1]["chain_p50"]
                if self.chain_samples else 0.0,
                "p99": self.chain_samples[-1]["chain_p99"]
                if self.chain_samples else 0.0,
            },
            "rows_activated": {
                "p50": percentile(self.rows_activated, 50),
                "p99": percentile(self.rows_activated, 99),
                "mean": finite(np.mean(self.rows_activated))
                if self.rows_activated else 0.0,
            },
        }

    def to_json(self, **extra) -> str:
        # allow_nan=False turns any non-finite scalar that slipped past the
        # finite() coercions into a hard error instead of invalid JSON
        return json.dumps({**self.snapshot(), **extra}, indent=2,
                          allow_nan=False)
