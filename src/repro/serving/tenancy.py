"""Multi-tenant namespaces over one shared HashMem key space.

A tenant id is folded into the high bits of every key, so all tenants share
the same physical table (and therefore the same bucket/chain/bit-plane
machinery, arena, and probe kernels) while their key spaces are disjoint by
construction: fold(a, k1) == fold(b, k2) implies a == b and k1 == k2.  This
is the serving analogue of the paper's virtualization layer — isolation is a
property of the key encoding, not of per-tenant replicas, so one tenant's
deletes, tombstones, and auto-grow rebuilds can never alias another tenant's
entries (rebuilds re-bucket by the folded key; see tests/test_tenancy.py).

Sentinel safety: the folded key domain must stay strictly below 0xFFFFFFF0
(ROUTE_PAD) — HashMem reserves 0xFFFFFFFF (EMPTY) and 0xFFFFFFFE
(TOMBSTONE), and the RLU/engine use 0xFFFFFFF0..0xFFFFFFFD as routing/batch
padding: a key in that range would be silently treated as padding (never
stored, probes always miss).  The workload generators keep raw keys below
0xFFFFFFF0, and the top (all-ones) tenant id is unregistrable because its
folded range reaches up into the reserved region; ``max_tenants`` excludes
it.  ``fold`` enforces the reserved floor with a real exception (not an
assert), so a mis-sized custom TenantSpace can't smuggle a reserved key
into the table even under ``python -O``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

TENANT_BITS = 8                       # default: 254 usable tenants
_RAW_SENTINEL_FLOOR = 0xFFFFFFF0      # kv_synth keeps raw keys below this


class TenantSpace:
    """Key folding for ``bits``-bit tenant ids over 32-bit keys."""

    def __init__(self, bits: int = TENANT_BITS):
        assert 0 < bits < 16
        self.bits = bits
        self.key_bits = 32 - bits
        self.max_tenants = (1 << bits) - 1          # top id hits sentinels
        self.key_space = 1 << self.key_bits

    def fold(self, tenant_id: int, keys):
        """(tenant_id, keys) -> folded uint32 keys (vectorized).  Raises
        ValueError when a tenant id or key is out of range, or when a
        folded key would land in the reserved pad/sentinel range
        [0xFFFFFFF0, 0xFFFFFFFF] (see module docstring)."""
        if not 0 <= tenant_id < self.max_tenants:
            raise ValueError(
                f"tenant id {tenant_id} out of range [0, {self.max_tenants})")
        keys = np.asarray(keys, np.uint64)
        if keys.size and not (keys < self.key_space).all():
            raise ValueError(f"tenant keys must fit {self.key_bits} bits")
        folded = ((np.uint64(tenant_id) << np.uint64(self.key_bits)) | keys) \
            .astype(np.uint32)
        if folded.size and int(folded.max()) >= _RAW_SENTINEL_FLOOR:
            raise ValueError(
                f"folded key {int(folded.max()):#x} collides with the "
                f"reserved pad/sentinel range "
                f"[{_RAW_SENTINEL_FLOOR:#x}, 0xffffffff]")
        return folded

    def unfold(self, folded):
        """Folded uint32 keys -> (tenant_ids, raw keys)."""
        folded = np.asarray(folded, np.uint64)
        return (folded >> np.uint64(self.key_bits)).astype(np.uint32), \
            (folded & np.uint64(self.key_space - 1)).astype(np.uint32)


@dataclass
class Tenant:
    """One tenant: identity plus admission-control quotas.

    ``max_slots`` bounds the tenant's concurrent in-flight requests (slot
    occupancy quota); ``max_pending`` bounds its queued backlog.  Either can
    be 0 for "no per-tenant bound" (the engine's global bounds still apply).
    """
    tid: int
    name: str = ""
    max_slots: int = 0
    max_pending: int = 0
    stats: dict = field(default_factory=lambda: {
        "submitted": 0, "rejected": 0, "queued": 0, "admitted": 0,
        "completed": 0, "killed": 0,
        "ops": {"read": 0, "update": 0, "insert": 0, "delete": 0,
                "scan": 0, "rmw": 0},
        "hits": 0, "misses": 0,
        # wall-time sums over COMPLETED requests: submit->admit (queue)
        # and admit->complete (service) — per-tenant view of the engine's
        # queue/service latency split (metrics.py histograms hold the
        # engine-wide quantiles)
        "queue_secs": 0.0, "service_secs": 0.0,
    })


class TenantRegistry:
    """Registered tenants + the shared key-folding space."""

    def __init__(self, bits: int = TENANT_BITS):
        self.space = TenantSpace(bits)
        self.tenants: dict[int, Tenant] = {}

    def register(self, name: str = "", max_slots: int = 0,
                 max_pending: int = 0, tid: int | None = None) -> Tenant:
        if tid is None:
            tid = len(self.tenants)
            while tid in self.tenants:
                tid += 1
        assert tid not in self.tenants, f"tenant {tid} already registered"
        assert 0 <= tid < self.space.max_tenants, \
            f"tenant id {tid} out of range [0, {self.space.max_tenants})"
        t = Tenant(tid=tid, name=name or f"tenant{tid}",
                   max_slots=max_slots, max_pending=max_pending)
        self.tenants[tid] = t
        return t

    def __getitem__(self, tid: int) -> Tenant:
        return self.tenants[tid]

    def __iter__(self):
        return iter(self.tenants.values())

    def fold(self, tid: int, keys):
        return self.space.fold(tid, keys)

    def stats(self) -> dict:
        return {t.name: {**t.stats, "ops": dict(t.stats["ops"])}
                for t in self}
