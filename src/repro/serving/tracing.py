"""Tick-level tracing for the serving engine: structured spans on a bounded
ring buffer, exported as Chrome/Perfetto trace-event JSON.

The :class:`Tracer` is zero-dependency and host-side: every record is a
plain tuple appended to a ``collections.deque(maxlen=capacity)``, so memory
is O(1) in run length and a dropped-oldest counter keeps the loss visible.
Four record families cover the engine's timeline:

  * **duration spans** — ``with tracer.span("gather", tid=lane): ...`` or
    the split form ``tok = tracer.begin("tick"); ...; tracer.end(tok)`` for
    spans that cross function boundaries (a pipelined tick is *begun* at
    issue and *ended* at drain, possibly several engine ticks later).
    ``tid`` is the track: the engine maps pipeline lanes to tracks so
    depth>=2 tick spans render side by side and a stall shows as a gap;
  * **counters** — ``tracer.counter("occupancy", v)``: per-tick counter
    tracks (occupancy, route-cap fill, rows activated, routed all_to_all
    element volume);
  * **instants** — ``tracer.instant("kill", rid=...)``: point events for
    request aborts and write-claim fence hits;
  * **async request lifecycle** — ``async_begin/async_end("queue", id=rid)``:
    submit→admit→complete slices (``request`` wrapping ``queue`` then
    ``service``) keyed by request id, so overlapping requests don't fight
    over one track.

``export(path)`` (or ``to_events()``) emits the Chrome trace-event JSON
array format: span records become balanced ``B``/``E`` pairs replayed
through a per-track nesting sweep (timestamps monotonic per track, children
clamped inside parents), counters become ``C`` events, instants ``i``, and
request slices ``b``/``e`` async pairs — openable directly in Perfetto
(https://ui.perfetto.dev) or chrome://tracing, and machine-checkable with
``tools/trace_report.py``.

A disabled tracer (``Tracer(enabled=False)``, the engine's default via
``NULL_TRACER``) keeps every record method a single attribute check, so the
untraced hot path pays one branch per call site; ``benchmarks/
serving_bench.py`` measures the *enabled* cost as the ``trace_overhead``
row, gated <=1.10x by ``tools/bench_check.py``.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager

# the documented span vocabulary (tools/trace_report.py groups by these;
# make trace-smoke asserts the core ones appear in a real mesh run)
SPAN_NAMES = (
    "tick",            # one engine tick, issue -> drain (lane track)
    "gather",          # per-slot op gather + claim/fence bookkeeping
    "route",           # two-pass routing capacity measurement (fused mesh)
    "probe", "delete", "insert",   # per-phase device-call dispatch
    "fused_tick",      # whole-tick megakernel dispatch (ONE shard_map)
    "writeback",       # drain: host materialization + result scatter
    "pipeline_stall",  # write-claim fence flush (depth>=2)
    "admit",           # completion sweep + slot refill
    "sample",          # throttled chain/rows-activated telemetry
    "grow",            # drain-time PR_ERROR repair, resize="rebuild"
    "split",           # same repair point, resize="extendible": per-group
                       # split/doubling — inline, NO pipeline flush
    "compact", "preload",
)
INSTANT_NAMES = ("kill", "write_fence", "deferred_write", "profiler_start",
                 "profiler_stop")
COUNTER_NAMES = ("occupancy", "tick_ops", "route_cap_fill", "routed_elems",
                 "rows_activated")
REQUEST_SLICES = ("request", "queue", "service")

_PID = 1                       # single-process engine: one trace pid

# record kinds in the ring (field layout per kind)
_SPAN, _COUNTER, _INSTANT, _ABEGIN, _AEND = 0, 1, 2, 3, 4


class Tracer:
    """Bounded-ring span recorder with Chrome trace-event export.

    ``capacity`` bounds the ring (oldest records dropped, counted in
    ``self.dropped``); ``enabled=False`` turns every method into a cheap
    no-op (the shared :data:`NULL_TRACER` is exactly that).
    """

    def __init__(self, capacity: int = 1 << 16, enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self._recorded = 0
        self._t0 = time.perf_counter()

    # -- clock -------------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since tracer creation (the trace timebase)."""
        return (time.perf_counter() - self._t0) * 1e6

    @property
    def dropped(self) -> int:
        return max(0, self._recorded - len(self._ring))

    def __len__(self) -> int:
        return len(self._ring)

    def _emit(self, rec: tuple):
        self._ring.append(rec)
        self._recorded += 1

    # -- spans -------------------------------------------------------------
    def begin(self, name: str, tid: int = 0, **args):
        """Open a span; returns a token for :meth:`end`.  Use for spans
        that outlive the current scope (the engine's tick span stays open
        across pipelined ticks until drain)."""
        if not self.enabled:
            return None
        return (name, tid, self.now_us(), args)

    def end(self, token):
        """Close a span opened by :meth:`begin` (None tokens no-op)."""
        if token is None or not self.enabled:
            return
        name, tid, ts, args = token
        self._emit((_SPAN, name, tid, ts, self.now_us() - ts, args))

    @contextmanager
    def span(self, name: str, tid: int = 0, **args):
        tok = self.begin(name, tid, **args) if self.enabled else None
        try:
            yield
        finally:
            self.end(tok)

    # -- counters / instants ----------------------------------------------
    def counter(self, name: str, value, tid: int = 0):
        if not self.enabled:
            return
        self._emit((_COUNTER, name, tid, self.now_us(), float(value), None))

    def instant(self, name: str, tid: int = 0, **args):
        if not self.enabled:
            return
        self._emit((_INSTANT, name, tid, self.now_us(), 0.0, args))

    # -- async request lifecycle ------------------------------------------
    def async_begin(self, name: str, id: int, **args):
        if not self.enabled:
            return
        self._emit((_ABEGIN, name, id, self.now_us(), 0.0, args))

    def async_end(self, name: str, id: int, **args):
        if not self.enabled:
            return
        self._emit((_AEND, name, id, self.now_us(), 0.0, args))

    # -- export ------------------------------------------------------------
    def to_events(self) -> list:
        """Chrome trace-event dicts from the current ring contents.

        Span records are replayed per track through a nesting sweep —
        sorted by (start, -duration), a child whose interval extends past
        its parent (float jitter) is clamped inside — so the emitted
        ``B``/``E`` stream is balanced and timestamp-monotonic per track by
        construction, even after ring drops removed arbitrary records.
        Async ``b`` records are emitted with their matching ``e`` (an
        unmatched half — its partner aged out of the ring, or the request
        never completed — is dropped rather than exported unbalanced).
        """
        spans: dict = {}
        others: list = []
        abegins: dict = {}
        apairs: list = []
        for rec in self._ring:
            kind = rec[0]
            if kind == _SPAN:
                spans.setdefault(rec[2], []).append(rec)
            elif kind == _COUNTER:
                others.append({"name": rec[1], "ph": "C", "pid": _PID,
                               "tid": rec[2], "ts": rec[3],
                               "args": {"value": rec[4]}})
            elif kind == _INSTANT:
                others.append({"name": rec[1], "ph": "i", "s": "t",
                               "pid": _PID, "tid": rec[2], "ts": rec[3],
                               "args": rec[5] or {}})
            elif kind == _ABEGIN:
                abegins[(rec[1], rec[2])] = rec
            else:
                b = abegins.pop((rec[1], rec[2]), None)
                if b is not None:
                    apairs.append((b, rec))
        events: list = []
        for tid, recs in spans.items():
            events.extend(self._sweep_track(tid, recs))
        for b, e in apairs:
            base = {"cat": "request", "name": b[1], "id": b[2], "pid": _PID}
            events.append({**base, "ph": "b", "ts": b[3], "args": b[5] or {}})
            events.append({**base, "ph": "e", "ts": e[3], "args": e[5] or {}})
        events.extend(others)
        events.sort(key=lambda ev: ev["ts"])       # stable: keeps B/E order
        if not events:
            return []
        meta = [{"name": "process_name", "ph": "M", "pid": _PID,
                 "args": {"name": "ServingEngine"}}]
        for tid in sorted(spans):
            meta.append({"name": "thread_name", "ph": "M", "pid": _PID,
                         "tid": tid, "args": {"name": f"lane {tid}"}})
        return meta + events

    @staticmethod
    def _sweep_track(tid: int, recs: list) -> list:
        """One track's span records -> balanced, monotonic B/E events."""
        recs = sorted(recs, key=lambda r: (r[3], -r[4]))
        out: list = []
        stack: list = []                 # (name, end_ts)
        for _, name, _, ts, dur, args in recs:
            while stack and stack[-1][1] <= ts:
                n, e = stack.pop()
                out.append({"name": n, "ph": "E", "pid": _PID, "tid": tid,
                            "ts": e})
            end = ts + dur
            if stack and end > stack[-1][1]:
                end = stack[-1][1]       # clamp float jitter inside parent
            out.append({"name": name, "ph": "B", "pid": _PID, "tid": tid,
                        "ts": ts, "args": args or {}})
            stack.append((name, end))
        while stack:
            n, e = stack.pop()
            out.append({"name": n, "ph": "E", "pid": _PID, "tid": tid,
                        "ts": e})
        return out

    def export(self, path: str, **metadata) -> int:
        """Write the trace-event JSON file; returns the event count."""
        events = self.to_events()
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"recorded": self._recorded,
                             "dropped": self.dropped, **metadata}}
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(events)


#: shared disabled tracer — the engine's default, so call sites never
#: branch on None (every record method is one ``self.enabled`` check)
NULL_TRACER = Tracer(capacity=1, enabled=False)
