# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benchmarks must see
# the single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (test_distributed.py,
# test_dryrun_small.py).
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (long randomized mutation schedules)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running randomized schedules (>1k ops); "
        "run with --runslow or REPRO_RUN_SLOW=1")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow") or os.environ.get("REPRO_RUN_SLOW"):
        return
    skip_slow = pytest.mark.skip(reason="needs --runslow (or REPRO_RUN_SLOW=1)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
