# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benchmarks must see
# the single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (test_distributed.py,
# test_dryrun_small.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
