"""Fingerprint-ablation smoke for `make ci` (also importable by tests).

Fingerprints are a pure page FILTER: they may only skip pages whose key
lane cannot contain the query, never change which slot a probe resolves
to.  So for any op schedule, a table built with ``fingerprint_bits > 0``
must be bit-equal — probe values, found masks, insert oks, delete founds —
to the same schedule on a table with fingerprints off, and both must match
the duplicate-aware DictModel oracle (tests/model.py).

``fp_smoke()`` runs mixed insert/probe/delete/grow churn schedules over
the (plain, displaced+stash) x (ref, perf) grid.  Displaced configs use
slots_per_page=32: the fingerprint lane rides the bit-plane packer, which
requires slot counts in multiples of 32.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.base import HashMemConfig
from repro.core import hashmap

from model import DictModel


def _cfg(backend: str, fp_bits: int, displacement: bool) -> HashMemConfig:
    return HashMemConfig(num_buckets=16, slots_per_page=32,
                         overflow_pages=64, max_chain=4, backend=backend,
                         fingerprint_bits=fp_bits,
                         displacement=displacement,
                         stash_slots=32 if displacement else 0,
                         auto_grow=False)


def _schedule(seed: int, rounds: int = 6, batch: int = 48):
    """Mixed churn: each round inserts fresh keys, probes a blend of live +
    missing keys, deletes ~a third of the live set, and round 3 doubles the
    table (grow) so the rebuild path is in the ablation too."""
    rng = np.random.default_rng(seed)
    pool = rng.choice(100_000, rounds * batch, replace=False) \
        .astype(np.uint32)
    live: list[int] = []
    sched = []
    for r in range(rounds):
        ks = pool[r * batch:(r + 1) * batch]
        sched.append(("insert", ks, ks * np.uint32(3) + np.uint32(1)))
        qs = np.concatenate([
            rng.choice(np.asarray(live + list(ks), np.uint32), batch),
            rng.choice(2**31, 8).astype(np.uint32) + np.uint32(2**31 - 2),
        ])
        sched.append(("probe", qs, None))
        live.extend(int(k) for k in ks)
        dead = rng.choice(len(live), len(live) // 3, replace=False)
        dk = np.asarray(live, np.uint32)[dead]
        sched.append(("delete", dk, None))
        gone = set(int(k) for k in dk)
        live = [k for k in live if k not in gone]
        if r == 2:
            sched.append(("grow", None, None))
        sched.append(("probe", np.asarray(live[-batch:] or [1],
                                          np.uint32), None))
    return sched


def _run(cfg: HashMemConfig, sched) -> list:
    hm = hashmap.create(cfg)
    out = []
    for kind, ks, vs in sched:
        if kind == "grow":
            hm = hashmap.grow(hm)
            continue
        k = jnp.asarray(ks)
        if kind == "insert":
            hm, ok = hashmap.insert(hm, k, jnp.asarray(vs))
            out.append(("insert", np.asarray(ok).tolist()))
        elif kind == "delete":
            hm, f = hashmap.delete(hm, k)
            out.append(("delete", np.asarray(f).tolist()))
        else:
            v, f = hashmap.probe(hm, k)
            out.append(("probe", np.asarray(v).tolist(),
                        np.asarray(f).tolist()))
    return out


def _model_run(sched) -> list:
    m = DictModel()
    out = []
    for kind, ks, vs in sched:
        if kind == "grow":
            continue
        if kind == "insert":
            ok = [True] * len(ks)          # ample arena: nothing refused
            m.insert(ks, vs, ok)
            out.append(("insert", ok))
        elif kind == "delete":
            out.append(("delete", [bool(b) for b in m.delete(ks)]))
        else:
            v, f = m.probe(ks)
            out.append(("probe", [int(x) for x in v],
                        [bool(b) for b in f]))
    return out


def fp_smoke(seeds=(0, 1)) -> None:
    for seed in seeds:
        sched = _schedule(seed)
        for displacement in (False, True):
            for backend in ("ref", "perf"):
                off = _run(_cfg(backend, 0, displacement), sched)
                on = _run(_cfg(backend, 10, displacement), sched)
                assert on == off, (
                    f"fingerprint ablation diverged: seed={seed} "
                    f"backend={backend} displacement={displacement}")
                oracle = _model_run(sched)
                assert on == oracle, (
                    f"fp-on run diverged from DictModel: seed={seed} "
                    f"backend={backend} displacement={displacement}")
        print(f"fp-smoke seed {seed}: "
              "fp on == fp off == DictModel (ref+perf, plain+displaced)")
    print("fp-smoke OK")


if __name__ == "__main__":
    fp_smoke()
