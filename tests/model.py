"""Pure-Python reference model for the HashMem differential tests.

Mirrors the exact observable semantics of ``repro.core.hashmap``:

  * duplicate keys are all stored; probe returns the OLDEST duplicate's
    value (first match in chain order == insertion order within a bucket,
    preserved across grow/compact rebuilds);
  * delete tombstones the oldest duplicate only; duplicate queries in one
    delete batch resolve to the same slot (a single removal, every query
    still reports found=True);
  * insert consumes the engine's per-element ok mask: elements the engine
    refused (PR_ERROR) are not applied to the model either — the model
    checks agreement of the *stored* state, while the harness separately
    asserts ok patterns where capacity is known.

The model is deliberately dumb: a dict of FIFO value lists.
"""
from __future__ import annotations

from collections import OrderedDict


class DictModel:
    """key (int) -> FIFO list of values (ints, oldest first)."""

    def __init__(self):
        self.d: dict[int, list[int]] = OrderedDict()

    # -- mutations ---------------------------------------------------------
    def insert(self, keys, vals, ok):
        for k, v, o in zip(keys, vals, ok):
            if bool(o):
                self.d.setdefault(int(k), []).append(int(v))

    def delete(self, keys):
        """Returns the expected found mask.  Duplicate keys in one batch hit
        the same slot: found for all, but only one element removed."""
        found = []
        removed_this_batch = set()
        for k in keys:
            k = int(k)
            lst = self.d.get(k)
            if lst:
                found.append(True)
                if k not in removed_this_batch:
                    lst.pop(0)
                    removed_this_batch.add(k)
                    if not lst:
                        del self.d[k]
            elif k in removed_this_batch:
                # emptied earlier in this batch: the hashmap resolved all
                # duplicates against the PRE-batch state, so still found
                found.append(True)
            else:
                found.append(False)
        return found

    # -- queries -----------------------------------------------------------
    def probe(self, keys):
        """Returns (expected values, expected found mask)."""
        vals, found = [], []
        for k in keys:
            lst = self.d.get(int(k))
            if lst:
                vals.append(lst[0])
                found.append(True)
            else:
                vals.append(0)
                found.append(False)
        return vals, found

    def live_entries(self) -> int:
        return sum(len(v) for v in self.d.values())

    def keys(self):
        return list(self.d.keys())
