"""Pure-Python reference model for the HashMem differential tests.

Mirrors the exact observable semantics of ``repro.core.hashmap``:

  * duplicate keys are all stored; probe returns the OLDEST duplicate's
    value (first match in chain order == insertion order within a bucket,
    preserved across grow/compact rebuilds);
  * delete tombstones the oldest duplicate only; duplicate queries in one
    delete batch resolve to the same slot (a single removal, every query
    still reports found=True);
  * insert consumes the engine's per-element ok mask: elements the engine
    refused (PR_ERROR) are not applied to the model either — the model
    checks agreement of the *stored* state, while the harness separately
    asserts ok patterns where capacity is known.

The model is deliberately dumb: a dict of FIFO value lists.  Resize
internals — full grow() rebuilds AND extendible group splits / directory
doublings — are invisible to it by design: a replayed schedule must
produce bit-identical results whether the engine rebuilt, split, or never
resized at all, which is exactly what makes the replay a differential
witness for split-during-pipelined-schedule runs (sharded_driver.grow_smoke).
"""
from __future__ import annotations

from collections import OrderedDict


class DictModel:
    """key (int) -> FIFO list of values (ints, oldest first)."""

    def __init__(self):
        self.d: dict[int, list[int]] = OrderedDict()

    # -- mutations ---------------------------------------------------------
    def insert(self, keys, vals, ok):
        for k, v, o in zip(keys, vals, ok):
            if bool(o):
                self.d.setdefault(int(k), []).append(int(v))

    def delete(self, keys):
        """Returns the expected found mask.  Duplicate keys in one batch hit
        the same slot: found for all, but only one element removed."""
        found = []
        removed_this_batch = set()
        for k in keys:
            k = int(k)
            lst = self.d.get(k)
            if lst:
                found.append(True)
                if k not in removed_this_batch:
                    lst.pop(0)
                    removed_this_batch.add(k)
                    if not lst:
                        del self.d[k]
            elif k in removed_this_batch:
                # emptied earlier in this batch: the hashmap resolved all
                # duplicates against the PRE-batch state, so still found
                found.append(True)
            else:
                found.append(False)
        return found

    # -- queries -----------------------------------------------------------
    def probe(self, keys):
        """Returns (expected values, expected found mask)."""
        vals, found = [], []
        for k in keys:
            lst = self.d.get(int(k))
            if lst:
                vals.append(lst[0])
                found.append(True)
            else:
                vals.append(0)
                found.append(False)
        return vals, found

    def live_entries(self) -> int:
        return sum(len(v) for v in self.d.values())

    def keys(self):
        return list(self.d.keys())


# ---------------------------------------------------------------------------
# Adversarial key mining (numpy mirror of repro.core.hashing) — keys that
# collide on the FIRST bucket choice, and optionally on the SECOND too, so
# displacement tests can force H2 relocation or defeat it into the stash.
# ---------------------------------------------------------------------------

MURMUR_SALT = 0x9E3779B9
B2_SALT = 0x68E31DA4          # keep in sync with repro.core.hashing


def murmur3_fmix_np(keys, salt: int = MURMUR_SALT):
    """numpy mirror of hashing.murmur3_fmix (uint32 wraparound arithmetic)."""
    import numpy as np
    h = np.asarray(keys, np.uint32) ^ np.uint32(salt)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    return h


def mine_bucket_colliding_keys(n: int, num_buckets: int,
                               same_b2: bool = True,
                               salt: int = MURMUR_SALT):
    """Mine ``n`` distinct user keys sharing the H1 bucket under the default
    murmur3_fmix hash; with ``same_b2`` each key's H2 equals its H1 (one
    shared bucket for BOTH choices), so H2 relocation is useless and
    inserts past the chain bound land in the stash.  With ``same_b2=False``
    every mined key has H2 != H1, guaranteeing displacement genuinely
    relocates."""
    import numpy as np
    # at density 1/B (or 1/B^2 for the b1==b2==b case) this is orders of
    # magnitude more candidates than needed for the small test tables
    cand = np.arange(1, 1 + max(1 << 16, 64 * n * num_buckets * num_buckets),
                     dtype=np.uint32)
    b1 = murmur3_fmix_np(cand, salt) % np.uint32(num_buckets)
    b2 = murmur3_fmix_np(cand, (salt ^ B2_SALT) & 0xFFFFFFFF) \
        % np.uint32(num_buckets)
    ok = (b1 == b2) if same_b2 else (b1 != b2)
    vals, counts = np.unique(b1[ok], return_counts=True)
    keys = cand[ok & (b1 == vals[counts.argmax()])][:n]
    assert len(keys) == n, f"mined only {len(keys)}/{n} colliding keys"
    return keys


# ---------------------------------------------------------------------------
# ServingEngine differential harness (shared by the in-process tests and the
# multi-device subprocess tests — keep this module import-light)
# ---------------------------------------------------------------------------

def make_engine_schedule(seed: int, n_requests: int = 24,
                         ops_per_request: int = 3, keyspace: int = 64,
                         zipf_theta: float = 0.0):
    """Deterministic random request streams (lists of op tuples) for the
    serving-engine differential tests.  ``zipf_theta`` > 0 skews key choice
    (YCSB-style hot keys -> heavy same-tick write contention and claim
    deferrals); 0 = uniform."""
    import numpy as np
    rng = np.random.default_rng(seed)
    if zipf_theta > 0:
        ranks = np.arange(1, keyspace + 1, dtype=np.float64)
        w = (1.0 / ranks ** zipf_theta)
        w /= w.sum()
    else:
        w = None

    def key():
        return int(rng.choice(keyspace, p=w))

    kinds = ["read", "update", "insert", "delete", "rmw", "scan"]
    probs = [0.28, 0.22, 0.20, 0.12, 0.10, 0.08]
    streams = []
    for _ in range(n_requests):
        ops = []
        for _ in range(ops_per_request):
            kind = kinds[int(rng.choice(len(kinds), p=probs))]
            v = int(rng.integers(1, 2**30))
            if kind == "read":
                ops.append(("read", key()))
            elif kind == "update":
                ops.append(("update", key(), v))
            elif kind == "insert":
                ops.append(("insert", key(), v))
            elif kind == "delete":
                ops.append(("delete", key()))
            elif kind == "rmw":
                ops.append(("rmw", key(), v))
            else:
                ops.append(("scan", key(), int(rng.integers(1, 4))))
        streams.append(ops)
    return streams


def make_insert_heavy_schedule(seed: int, n_requests: int = 48,
                               ops_per_request: int = 3, keyspace: int = 96,
                               zipf_theta: float = 0.0,
                               insert_frac: float = 0.5):
    """Insert-dominated request streams — the growth-forcing counterpart of
    ``make_engine_schedule``, shared by the grow/split differential smokes
    and the p99-under-growth bench.  ``insert_frac`` of ops are inserts;
    the rest split 2:2:1 update/read/delete.  ``zipf_theta`` > 0 skews the
    key choice so chain overflow concentrates on hot buckets (the case
    where an extendible split beats a full rebuild)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    if zipf_theta > 0:
        ranks = np.arange(1, keyspace + 1, dtype=np.float64)
        w = 1.0 / ranks ** zipf_theta
        w /= w.sum()
    else:
        w = None
    rest = (1.0 - insert_frac) / 5.0
    probs = [insert_frac, 2 * rest, 2 * rest, rest]
    streams = []
    for _ in range(n_requests):
        ops = []
        for _ in range(ops_per_request):
            k = int(rng.choice(keyspace, p=w))
            v = int(rng.integers(1, 2**20))
            kind = ["insert", "update", "read", "delete"][
                int(rng.choice(4, p=probs))]
            ops.append({"insert": ("insert", k, v),
                        "update": ("update", k, v),
                        "read": ("read", k),
                        "delete": ("delete", k)}[kind])
        streams.append(ops)
    return streams


def replay_schedule_against_model(schedule, model: "DictModel" = None):
    """Replay a ServingEngine ``record_schedule`` log against the DictModel
    and assert every recorded result.  The log is in gather order; within a
    tick the engine executes fixed phases (probe -> delete -> insert), so
    the model is driven phase by phase per tick.  Returns the model."""
    model = model or DictModel()
    by_tick: dict = {}
    for tick, kind, keys, val, res in schedule:
        by_tick.setdefault(tick, []).append((kind, keys, val, res))
    for tick in sorted(by_tick):
        ops = by_tick[tick]
        # phase 1: probes (read / scan / rmw pre-read)
        for kind, keys, val, res in ops:
            if kind == "read" or kind == "rmw":
                ev, ef = model.probe([keys[0]])
                field = "value" if kind == "read" else "old"
                assert res["found"] == ef[0], (tick, kind, keys, res)
                if ef[0]:
                    assert res[field] == ev[0], (tick, kind, keys, res)
            elif kind == "scan":
                ev, ef = model.probe(list(keys))
                assert res["found"] == ef, (tick, keys, res)
                for i, f in enumerate(ef):
                    if f:
                        assert res["values"][i] == ev[i], (tick, keys, res)
        # phase 2: deletes (delete / update / rmw tombstone)
        for kind, keys, val, res in ops:
            if kind in ("delete", "update", "rmw"):
                ef = model.delete([keys[0]])
                field = "found" if kind == "delete" else "replaced"
                assert res[field] == ef[0], (tick, kind, keys, res)
        # phase 3: inserts (insert / update / rmw append), gated on the
        # engine's own ok verdict so fixed-arena refusals stay in sync
        for kind, keys, val, res in ops:
            if kind in ("insert", "update", "rmw"):
                model.insert([keys[0]], [val], [res["ok"]])
    return model
