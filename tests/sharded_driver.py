"""Driver for the mesh-backed ServingEngine differential tests.

Runs INSIDE the multi-device subprocesses spawned by
tests/test_serving_sharded.py (XLA_FLAGS=--xla_force_host_platform_device_count
must be set before jax import, so the pytest process itself stays
single-device).  PYTHONPATH includes both src/ and tests/.

One ``sweep`` call runs many randomized schedules; for each schedule the
same request streams are executed by

  * the host-shard engine (coalesced)        — the PR-3 reference path;
  * the mesh engine, pipelining OFF (depth 1);
  * the mesh engine, pipelining ON  (each depth in ``depths``);
  * every Nth schedule: the mesh engine with coalesce=False (per-request);

and every run is checked three ways: results bit-equal to the host
reference, the recorded schedule replays exactly against the DictModel
(the sequential serialization witness), and per-shard state is consistent
— shard live entries sum to the model population and every shard holds
only keys the RLU router assigns to it.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import HashMemConfig
from repro.core import rlu
from repro.launch.mesh import make_serving_mesh
from repro.serving import Request, ServingEngine

from model import (DictModel, make_engine_schedule,
                   make_insert_heavy_schedule,
                   replay_schedule_against_model)


def _cfg(auto_grow: bool = True, displaced: bool = False) -> HashMemConfig:
    if displaced:
        # fingerprint lane rides the bit-plane packer: slots must be a
        # multiple of 32, hence the wider pages here
        return HashMemConfig(num_buckets=16, slots_per_page=32,
                             overflow_pages=32, max_chain=4, backend="ref",
                             auto_grow=auto_grow, displacement=True,
                             fingerprint_bits=8, stash_slots=32)
    return HashMemConfig(num_buckets=16, slots_per_page=8, overflow_pages=32,
                         max_chain=4, backend="ref", auto_grow=auto_grow)


def run_streams(streams, *, cfg, mesh=None, num_shards=2, coalesce=True,
                pipeline_depth=1, max_slots=8, preload=None,
                fused_tick=None):
    eng = ServingEngine(cfg, mesh=mesh, num_shards=num_shards,
                        max_slots=max_slots, coalesce=coalesce,
                        pipeline_depth=pipeline_depth, record_schedule=True,
                        fused_tick=fused_tick)
    if preload is not None:
        eng.preload(*preload)
    reqs = [Request(ops=list(ops)) for ops in streams]
    eng.submit_all(reqs)
    eng.run()
    return eng, [r.results for r in reqs]


def _shard_live_keys(hm) -> np.ndarray:
    """All live user keys on one shard — chain pages plus (when the config
    enables displacement) the stash lane."""
    kp = np.asarray(hm.key_pages).reshape(-1)
    live = kp[(kp != np.uint32(0xFFFFFFFF)) & (kp != np.uint32(0xFFFFFFFE))]
    if hm.store.stash is not None:
        sk = np.asarray(hm.store.stash)[:, 0]
        live = np.concatenate(
            [live, sk[(sk != np.uint32(0xFFFFFFFF)) &
                      (sk != np.uint32(0xFFFFFFFE))]])
    return live


def check_shard_state(eng, model):
    """Per-shard invariants: live entries sum to the model population and
    every live key lives on the shard the router assigns it to."""
    shards = eng.shards
    total = 0
    for s, hm in enumerate(shards):
        live = _shard_live_keys(hm)
        total += live.size
        if eng.backend.is_mesh and live.size:
            owners = rlu.owner_of_np(live, eng.backend.cfg, eng.num_shards,
                                     eng.shard_by)
            assert (owners == s).all(), \
                f"shard {s} holds foreign keys {live[owners != s][:8]}"
    assert total == model.live_entries(), (total, model.live_entries())


def one_schedule(seed: int, mesh, depths=(2,), per_request: bool = False,
                 zipf_theta: float = 0.0, displaced: bool = False):
    streams = make_engine_schedule(seed, n_requests=16, ops_per_request=3,
                                   keyspace=48, zipf_theta=zipf_theta)
    rng = np.random.default_rng(seed)
    pk = rng.choice(48, 16, replace=False).astype(np.uint32)
    pv = rng.integers(1, 2**30, 16).astype(np.uint32)
    preload = (pk, pv)

    host, ref = run_streams(streams, cfg=_cfg(displaced=displaced),
                            num_shards=2, preload=preload)
    model = replay_schedule_against_model(host.schedule, _seeded_model(pk, pv))
    check_shard_state(host, model)

    # mesh runs default to the FUSED whole-tick megakernel; "mesh_unfused"
    # keeps the three-call reference path, so every schedule bit-compares
    # fused vs unfused (both against the host reference)
    runs = {"mesh_d1": dict(mesh=mesh, pipeline_depth=1),
            "mesh_unfused": dict(mesh=mesh, fused_tick=False)}
    for d in depths:
        runs[f"mesh_d{d}"] = dict(mesh=mesh, pipeline_depth=d)
    if per_request:
        runs["mesh_per_request"] = dict(mesh=mesh, coalesce=False)
    for name, kw in runs.items():
        eng, results = run_streams(streams, cfg=_cfg(displaced=displaced),
                                   preload=preload, **kw)
        assert results == ref, \
            (name, seed, [d for d in zip(ref, results) if d[0] != d[1]][:1])
        m = replay_schedule_against_model(eng.schedule, _seeded_model(pk, pv))
        check_shard_state(eng, m)
        fused = kw.get("fused_tick", kw.get("coalesce", True)) is not False
        if fused:
            assert eng.batch_calls["fused_tick"] > 0, (name, eng.batch_calls)
            assert eng.batch_calls["probe"] == eng.batch_calls["delete"] \
                == eng.batch_calls["insert"] == 0, (name, eng.batch_calls)
        else:
            assert eng.batch_calls["fused_tick"] == 0, (name, eng.batch_calls)
    return True


def _seeded_model(pk, pv):
    m = DictModel()
    m.insert(pk, pv, np.ones(len(pk), bool))
    return m


def sweep(seed0: int, n: int, depths=(2,), zipfian: str = "mixed",
          per_request_every: int = 8, displaced: bool = False):
    """zipfian: "none" (uniform keys), "all" (every schedule contended),
    or "mixed" (alternate).  ``displaced`` runs every schedule on the
    fingerprint+displacement+stash config instead of the plain one."""
    mesh = make_serving_mesh()     # all forced devices
    for i in range(n):
        seed = seed0 + i
        hot = {"none": False, "all": True, "mixed": bool(i % 2)}[zipfian]
        one_schedule(seed, mesh, depths=depths,
                     per_request=(i % per_request_every == 0),
                     zipf_theta=0.99 if hot else 0.0, displaced=displaced)
    print(f"SWEEP OK {n} schedules (seeds {seed0}..{seed0 + n - 1})")


def grow_under_pipeline(seed: int = 5):
    """Force synchronized growth inside a pipelined window: tiny arena +
    insert-heavy streams; assert no lost or duplicated keys vs the model."""
    mesh = make_serving_mesh()
    cfg = HashMemConfig(num_buckets=4, slots_per_page=4, overflow_pages=8,
                        max_chain=2, backend="ref", auto_grow=True,
                        max_load_factor=0.95)
    rng = np.random.default_rng(seed)
    streams = []
    for r in range(48):
        ops = []
        for _ in range(3):
            k, v = int(rng.integers(0, 96)), int(rng.integers(1, 2**20))
            kind = rng.choice(["insert", "update", "read", "delete"],
                              p=[0.5, 0.2, 0.2, 0.1])
            ops.append({"insert": ("insert", k, v), "update": ("update", k, v),
                        "read": ("read", k), "delete": ("delete", k)}[kind])
        streams.append(ops)

    ref_eng, ref = run_streams(streams, cfg=cfg, num_shards=2)
    eng, results = run_streams(streams, cfg=cfg, mesh=mesh, pipeline_depth=2)
    assert eng.grow_events >= 1, "schedule never forced a grow"
    assert results == ref
    model = replay_schedule_against_model(eng.schedule, DictModel())
    check_shard_state(eng, model)
    # no lost keys: every model entry probes back with the oldest value
    keys = np.asarray(model.keys(), np.uint32)
    if keys.size:
        exp = np.asarray([model.d[int(k)][0] for k in keys], np.uint32)
        got = np.zeros(len(keys), np.uint32)
        fnd = np.zeros(len(keys), bool)
        for s, hm in enumerate(eng.shards):
            owners = rlu.owner_of_np(keys, eng.backend.cfg, eng.num_shards,
                                     eng.shard_by)
            m = owners == s
            if m.any():
                import jax.numpy as jnp
                v, f = rlu._local_probe(hm, jnp.asarray(keys[m]),
                                        eng.backend.cfg, eng.num_shards,
                                        eng.shard_by)
                got[m], fnd[m] = np.asarray(v), np.asarray(f)
        assert fnd.all(), "grow lost keys"
        assert (got == exp).all(), "grow corrupted values"
    # no duplicated keys: per-key copy counts match the model exactly
    counts: dict = {}
    for hm in eng.shards:
        kp = np.asarray(hm.key_pages).reshape(-1)
        live = kp[(kp != np.uint32(0xFFFFFFFF)) & (kp != np.uint32(0xFFFFFFFE))]
        for k in live:
            counts[int(k)] = counts.get(int(k), 0) + 1
    assert counts == {k: len(v) for k, v in model.d.items()}, \
        "grow duplicated keys"
    print("GROW-UNDER-PIPELINE OK", eng.grow_events, "grows,",
          eng.stall_events, "stalls")


def grow_smoke(trace_out: str = "", seed: int = 9):
    """`make grow-smoke`: extendible resize under a pipelined mesh schedule.

    2 forced devices, pipeline depth 2, tiny extendible table, insert-heavy
    streams that overflow hot chains — forcing >= 2 group splits (plus
    directory doublings) to repair mid-pipeline refused inserts.  Checked
    three ways: results bit-equal to the host-shard reference engine, the
    recorded schedule replays exactly against the DictModel, and the trace
    (written to ``trace_out`` for trace_report.py) must contain "split"
    spans and NO "grow" span — a split repairs inline without rebuilding
    any shard or flushing the pipeline."""
    mesh = make_serving_mesh()
    # the arena is sized so splits alone absorb the whole stream: a split
    # leaks its old chain pages (pim_malloc stays a bump pointer; compact()
    # reclaims), so overflow_pages must cover live data + leak slack —
    # otherwise the run degrades to the grow() rebuild fallback the trace
    # assertion is here to forbid
    cfg = HashMemConfig(num_buckets=4, slots_per_page=4, overflow_pages=60,
                        max_chain=2, backend="ref", auto_grow=True,
                        resize="extendible", max_load_factor=1.0)
    streams = make_insert_heavy_schedule(seed, n_requests=48,
                                         ops_per_request=3, keyspace=96,
                                         zipf_theta=0.6)

    ref_eng, ref = run_streams(streams, cfg=cfg, num_shards=2)
    eng = ServingEngine(cfg, mesh=mesh, max_slots=8, pipeline_depth=2,
                        record_schedule=True, trace=bool(trace_out))
    reqs = [Request(ops=list(ops)) for ops in streams]
    eng.submit_all(reqs)
    eng.run()
    results = [r.results for r in reqs]

    assert eng.split_events >= 2, \
        f"schedule never forced >= 2 splits (got {eng.split_events})"
    assert eng.grow_events == 0, \
        f"extendible run fell back to {eng.grow_events} full rebuild(s)"
    assert results == ref, "extendible mesh run diverged from host reference"
    model = replay_schedule_against_model(eng.schedule, DictModel())
    check_shard_state(eng, model)
    st = eng.stats()
    assert st["resize"] == "extendible" and st["split_events"] >= 2, st
    if trace_out:
        eng.export_trace(trace_out)
    print("GROW-SMOKE OK", eng.split_events, "splits,",
          eng.directory_doublings, "doublings,", eng.grow_events, "rebuilds,",
          eng.stall_events, "stalls")


def keys_owned_by(shard: int, n: int, cfg, num_shards: int,
                  shard_by: str = "highbits", start: int = 0) -> np.ndarray:
    """First ``n`` keys >= start that the RLU router assigns to ``shard`` —
    the raw material for adversarial all-keys-to-one-shard schedules."""
    out, k = [], start
    while len(out) < n:
        batch = np.arange(k, k + 4096, dtype=np.uint32)
        owners = rlu.owner_of_np(batch, cfg, num_shards, shard_by)
        out.extend(batch[owners == shard][:n - len(out)].tolist())
        k += 4096
    return np.asarray(out, np.uint32)


def fused_worst_skew(seed: int = 7):
    """Adversarial skew: EVERY key routes to shard 0, so the measured
    per-(src,dst) max equals the whole local batch — capacity must rise to
    Q_local (never truncate) and results must still be bit-equal to the
    host reference and the model."""
    mesh = make_serving_mesh()
    cfg = _cfg()
    D = mesh.shape["model"]
    hot = keys_owned_by(0, 64, cfg, D)
    rng = np.random.default_rng(seed)
    streams = []
    for r in range(16):
        ops = []
        for _ in range(3):
            k = int(rng.choice(hot))
            v = int(rng.integers(1, 2**20))
            kind = rng.choice(["insert", "read", "update", "delete"],
                              p=[0.4, 0.3, 0.2, 0.1])
            ops.append({"insert": ("insert", k, v), "read": ("read", k),
                        "update": ("update", k, v),
                        "delete": ("delete", k)}[kind])
        streams.append(ops)
    preload = (hot[:16], np.arange(1, 17, dtype=np.uint32))

    host, ref = run_streams(streams, cfg=cfg, num_shards=D, preload=preload)
    eng, results = run_streams(streams, cfg=cfg, mesh=mesh, preload=preload)
    assert results == ref, "worst-skew fused tick diverged from host"
    model = replay_schedule_against_model(eng.schedule,
                                          _seeded_model(*preload))
    check_shard_state(eng, model)
    # two-pass capacity: tracked the measured max, and never truncated —
    # every recorded cap is >= the exact measured per-(src,dst) count
    assert eng.route_cap_log, "fused engine recorded no routing capacities"
    for rec in eng.route_cap_log:
        for ql, cap, mx in zip(rec["q_local"], rec["cap"], rec["max"]):
            assert mx <= cap <= ql, rec
    print("WORST-SKEW OK", len(eng.route_cap_log), "fused launches")


def fused_smoke(n: int = 4):
    """Fast fused-vs-unfused guard for `make ci`: a handful of schedules on
    2 forced devices, fused and three-call mesh paths both bit-compared to
    the host reference (one_schedule does exactly that), plus the
    worst-skew capacity check."""
    mesh = make_serving_mesh()
    for i in range(n):
        one_schedule(6000 + i, mesh, depths=(2,), per_request=False,
                     zipf_theta=0.99 if i % 2 else 0.0)
    fused_worst_skew()
    print(f"FUSED SMOKE OK {n} schedules")


def kill_mid_pipeline(seed: int = 11):
    """Kill a request between pipelined ticks (its ops partially issued and
    still in flight); assert the slot is reclaimed and reused, remaining
    ops never execute, and the table state matches the model built from
    what actually ran."""
    from repro.distributed.fault_tolerance import FailureInjector, \
        InjectedFailure
    mesh = make_serving_mesh()
    cfg = _cfg()
    eng = ServingEngine(cfg, mesh=mesh, max_slots=4, pipeline_depth=2,
                        record_schedule=True)
    victim = Request(ops=[("insert", 100, 1), ("insert", 101, 2),
                          ("insert", 102, 3), ("insert", 103, 4)])
    others = [Request(ops=[("insert", k, k), ("read", k), ("read", k)])
              for k in range(8)]
    eng.submit_all([victim] + others)
    backlog = [Request(ops=[("read", k)]) for k in range(4)]

    inj = FailureInjector(fail_at_steps=(2,))
    while not eng.pool.idle() or eng._inflight:
        try:
            inj.check(eng.ticks)
        except InjectedFailure:
            # client died mid-flight: tick 2's ops are issued but undrained
            assert eng._inflight, "expected in-flight work at the kill point"
            assert eng.kill(victim)
            eng.submit_all(backlog)       # freed slot must be reusable
        if eng.pool.idle() and eng._inflight:
            eng.flush()
        else:
            eng.tick()
    assert victim.killed and victim.cursor < len(victim.ops), \
        "victim ran to completion despite the kill"
    assert all(r.done() for r in others + backlog)
    assert eng.killed_requests == 1
    # slot/page reclamation: occupancy drained, and the table holds exactly
    # what the executed schedule says (issued victim ops included, un-issued
    # ones absent)
    assert eng.pool.occupancy() == 0
    model = replay_schedule_against_model(eng.schedule, DictModel())
    check_shard_state(eng, model)
    executed = {ks[0] for _, kind, ks, _, _ in eng.schedule
                if kind == "insert"}
    unissued = {op[1] for op in victim.ops[victim.cursor:]}
    assert unissued.isdisjoint(executed), "killed ops still executed"
    print("KILL-MID-PIPELINE OK cursor", victim.cursor)
