"""Bench-trajectory gate unit tests (ISSUE 6 bugfix).

The old gate compared the newest run against the best of ALL prior runs,
so a single fluke-fast run ratcheted the bar forever; and a metric
appearing for the first time was skipped silently.  ``check_runs`` now
windows the baseline (best of the last K prior runs) and surfaces
first-appearance metrics as warnings.
"""
import json

import pytest

from tools.bench_check import DEFAULT_WINDOW, check_file, check_runs


def _run(**metrics):
    return dict(metrics)


def _row_run(name, **metrics):
    return {"rows": [{"name": name, **metrics}]}


def test_fluke_outside_window_does_not_fail():
    """A one-off 10x-fast fluke ages out of the window: runs at the steady
    level keep passing once the fluke is > window runs old."""
    fluke = _run(ops_per_sec=10_000.0)
    steady = [_run(ops_per_sec=1_000.0) for _ in range(DEFAULT_WINDOW)]
    newest = _run(ops_per_sec=950.0)
    runs = [fluke] + steady + [newest]
    failures, warnings, compared = check_runs(runs, threshold=1.5)
    assert failures == [] and warnings == []
    assert compared == 1
    # ... but with window=0 (old best-of-ALL behaviour) the fluke still
    # ratchets the bar and the same trajectory fails
    failures0, _, _ = check_runs(runs, threshold=1.5, window=0)
    assert len(failures0) == 1
    assert failures0[0][0] == "ops_per_sec"


def test_fluke_inside_window_still_guards():
    """A recent (in-window) best IS the baseline — a real cliff right
    after a fast run must still fail."""
    runs = [_run(ops_per_sec=1_000.0), _run(ops_per_sec=1_000.0),
            _run(ops_per_sec=100.0)]
    failures, _, _ = check_runs(runs, threshold=1.5)
    assert len(failures) == 1
    name, direction, best, newest, ratio = failures[0]
    assert name == "ops_per_sec" and direction == "up"
    assert ratio == pytest.approx(10.0)


def test_new_metric_warns_instead_of_silent_skip():
    runs = [_row_run("mesh", ops_per_sec=500.0),
            {"rows": [{"name": "mesh", "ops_per_sec": 510.0},
                      {"name": "mesh_fused", "ops_per_sec": 900.0,
                       "calls_per_tick": 1.0}]}]
    failures, warnings, compared = check_runs(runs, threshold=1.5)
    assert failures == []
    assert set(warnings) == {"mesh_fused.ops_per_sec",
                             "mesh_fused.calls_per_tick"}
    assert compared == 1  # only the pre-existing mesh row was guarded


def test_new_metric_guarded_from_next_run_on():
    runs = [_row_run("m", calls_per_tick=1.0),
            _row_run("m", calls_per_tick=3.0)]
    failures, warnings, _ = check_runs(runs, threshold=1.5)
    assert warnings == []
    assert len(failures) == 1
    name, direction, best, newest, ratio = failures[0]
    # calls_per_tick is lower-better: regressing 1 -> 3 launches trips it
    assert name == "m.calls_per_tick" and direction == "down"
    assert ratio == pytest.approx(3.0)


def test_lower_better_regression_direction():
    runs = [_run(us_per_probe=2.0), _run(us_per_probe=2.1)]
    failures, _, _ = check_runs(runs, threshold=1.5)
    assert failures == []  # within band (noisy metric gets 2x band anyway)
    runs = [_run(insert_ms=2.0), _run(insert_ms=4.0)]
    failures, _, _ = check_runs(runs, threshold=1.5)
    assert len(failures) == 1 and failures[0][1] == "down"


def test_skip_fields_never_guarded():
    runs = [_run(route_cap_mean=2.0, wall_seconds=1.0, stall_events=0.0),
            _run(route_cap_mean=64.0, wall_seconds=50.0, stall_events=9.0)]
    failures, warnings, compared = check_runs(runs, threshold=1.5)
    assert failures == [] and warnings == [] and compared == 0


def test_check_file_end_to_end(tmp_path, capsys):
    path = tmp_path / "BENCH_x.json"
    path.write_text(json.dumps({"runs": [
        _row_run("k", ops_per_sec=1000.0),
        {"rows": [{"name": "k", "ops_per_sec": 980.0,
                   "new_thing_ops_per_sec": 5.0}]},
    ]}))
    failures = check_file(str(path), threshold=1.5)
    out = capsys.readouterr().out
    assert failures == []
    assert "NEW METRIC k.new_thing_ops_per_sec" in out
    # regression path
    path.write_text(json.dumps({"runs": [
        _row_run("k", ops_per_sec=1000.0),
        _row_run("k", ops_per_sec=100.0),
    ]}))
    failures = check_file(str(path), threshold=1.5)
    assert len(failures) == 1
    assert "REGRESSION" in capsys.readouterr().out
