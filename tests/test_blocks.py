"""Block-level numerics: chunkwise-parallel forms vs exact recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import attention, mamba, xlstm
from repro.models.layers import is_leaf


def strip(tree):
    return jax.tree.map(lambda t: t[0], tree, is_leaf=is_leaf)


def test_mamba_chunked_equals_recurrent():
    cfg = smoke_config("jamba-v0.1-52b")
    p = strip(mamba.init(jax.random.PRNGKey(2), cfg))
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model)) * 0.5
    y_c = mamba.apply(p, cfg, x, chunk=8)
    st = mamba.init_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = mamba.decode_step(p, cfg, st, x[:, t:t + 1])
        ys.append(y)
    y_n = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_mlstm_chunk_invariance(chunk):
    cfg = smoke_config("xlstm-1.3b")
    p = strip(xlstm.init_mlstm(jax.random.PRNGKey(0), cfg))
    B, S = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_ref = xlstm.apply_mlstm(p, cfg, x, chunk=S)  # single chunk = parallel form
    y = xlstm.apply_mlstm(p, cfg, x, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


def test_mlstm_chunked_equals_decode():
    cfg = smoke_config("xlstm-1.3b")
    p = strip(xlstm.init_mlstm(jax.random.PRNGKey(0), cfg))
    B, S = 2, 48
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_c = xlstm.apply_mlstm(p, cfg, x, chunk=16)
    st = xlstm.init_mlstm_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = xlstm.decode_mlstm(p, cfg, st, x[:, t:t + 1])
        ys.append(y)
    y_n = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=2e-4, atol=2e-5)


def test_slstm_scan_equals_decode():
    cfg = smoke_config("xlstm-1.3b")
    p = strip(xlstm.init_slstm(jax.random.PRNGKey(4), cfg))
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model)) * 0.5
    y_s = xlstm.apply_slstm(p, cfg, x)
    st = xlstm.init_slstm_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = xlstm.decode_slstm(p, cfg, st, x[:, t:t + 1])
        ys.append(y)
    y_n = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_n),
                               rtol=2e-4, atol=2e-5)


def test_chunked_attention_matches_dense():
    cfg = smoke_config("llama3-8b").replace(dtype="float32")
    B, S, H, K, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd))
    out = attention.chunked_attention(q, k, v, cfg, causal=True, chunk=16)
    # dense reference
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgqc,bckh->bkgqh", p, v).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_chunked_attention_sliding_window():
    cfg = smoke_config("h2o-danube-1.8b").replace(dtype="float32",
                                                  sliding_window=24)
    B, S, H, K, hd = 1, 64, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd))
    out = attention.chunked_attention(q, k, v, cfg, causal=True, chunk=16)
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k) * hd ** -0.5
    i, j = jnp.meshgrid(jnp.arange(S), jnp.arange(S), indexing="ij")
    mask = (i >= j) & (i - j < 24)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgqc,bckh->bkgqh", p, v).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
