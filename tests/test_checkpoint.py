import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 2, timeout: int = 600):
    """Subprocess with forced host devices (same pattern as
    test_serving_sharded.py) — keeps the main pytest process on the single
    real CPU device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests")])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip_bitexact(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    ck.save(7, t)
    assert ck.latest_step() == 7
    got = ck.restore(7, jax.eval_shape(lambda: tree()))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(1, tree(1))
    ck.wait()
    got = ck.restore(1, jax.eval_shape(lambda: tree(1)))
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree(1)["a"]))


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, tree())
    d = tmp_path / "step_00000003"
    manifest = json.loads((d / "manifest.json").read_text())
    name = next(k for k, v in manifest["arrays"].items()
                if v["shape"] == [16, 8])
    fn = manifest["arrays"][name]["file"]
    arr = np.load(d / fn)
    arr[0, 0] += 1
    np.save(d / fn, arr)
    with pytest.raises(IOError, match="corruption"):
        ck.restore(3, jax.eval_shape(lambda: tree()))


def test_gc_keeps_last_three(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    for s in range(5):
        ck.save(s, {"x": jnp.zeros(3)})
    assert sorted(ck.all_steps()) == [2, 3, 4]


def test_atomicity_no_partial_dir(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, tree())
    assert not list(tmp_path.glob("tmp.*"))


# ---------------------------------------------------------------------------
# HashMem round-trips: the serving-table pytree with ALL optional lanes
# (fingerprints, stash, stash_fill/free_top scalars) must survive
# save -> restore bit-exactly, including onto a different mesh topology
# ---------------------------------------------------------------------------

def _displaced_cfg():
    from repro.configs.base import HashMemConfig
    return HashMemConfig(num_buckets=16, slots_per_page=32,
                         overflow_pages=16, max_chain=1, backend="ref",
                         fingerprint_bits=8, displacement=True,
                         stash_slots=32)


def test_hashmem_displaced_roundtrip_bitexact(tmp_path):
    """A displaced+stash HashMem (fingerprint lane, stash lane, stash_fill
    and free_top scalars all populated) round-trips through the
    checkpointer with bit-identical leaves AND bit-identical probe
    results."""
    from repro.core import hashmap
    from model import mine_bucket_colliding_keys

    cfg = _displaced_cfg()
    # same-H2 colliders defeat displacement: the chain fills, the overflow
    # lands in the stash, so stash_fill > 0 is actually exercised
    keys = mine_bucket_colliding_keys(36, cfg.num_buckets, same_b2=True)
    vals = np.arange(1, 37, dtype=np.uint32) * 5
    hm, ok = hashmap.insert(hashmap.create(cfg), jnp.asarray(keys),
                            jnp.asarray(vals))
    assert bool(np.asarray(ok).all())
    assert int(np.asarray(hm.store.stash_fill)) > 0

    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(11, hm)
    got = ck.restore(11, hashmap.create(cfg))
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(hm)[0],
            jax.tree_util.tree_flatten_with_path(got)[0]):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), str(pa))

    qs = np.concatenate([keys, keys + 7_000_000]).astype(np.uint32)
    v0, f0 = hashmap.probe(hm, jnp.asarray(qs))
    v1, f1 = hashmap.probe(got, jnp.asarray(qs))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))
    assert bool(np.asarray(f1)[:36].all())


def test_hashmem_extendible_roundtrip_keeps_directory(tmp_path):
    """An extendible table that has split (uneven local depths, leaked
    pages, widened directory) restores with the directory and depth lane
    intact — probes resolve through the restored directory bit-exactly."""
    from repro.configs.base import HashMemConfig
    from repro.core import hashmap
    from model import mine_bucket_colliding_keys

    cfg = HashMemConfig(num_buckets=8, slots_per_page=4, overflow_pages=120,
                        max_chain=2, backend="ref", auto_grow=True,
                        resize="extendible", max_load_factor=1.0)
    keys = mine_bucket_colliding_keys(20, cfg.num_buckets, same_b2=False)
    events: dict = {}
    hm, ok = hashmap.insert_extendible(
        hashmap.create(cfg), jnp.asarray(keys),
        jnp.arange(1, 21, dtype=jnp.uint32), events=events)
    assert bool(np.asarray(ok).all()) and events.get("splits", 0) >= 1

    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(4, hm)
    # the directory WIDTH is config-derived: restore targets the grown cfg
    got = ck.restore(4, hashmap.create(hm.config))
    np.testing.assert_array_equal(np.asarray(hm.bucket_head),
                                  np.asarray(got.bucket_head))
    np.testing.assert_array_equal(np.asarray(hm.store.local_depth),
                                  np.asarray(got.store.local_depth))
    st = hashmap.stats(got)
    assert st["max_local_depth"] > st["min_local_depth"]
    v, f = hashmap.probe(got, jnp.asarray(keys))
    assert bool(np.asarray(f).all())
    np.testing.assert_array_equal(np.asarray(v),
                                  np.arange(1, 21, dtype=np.uint32))


def test_hashmem_elastic_restore_onto_mesh(tmp_path):
    """Elastic restore: a stacked 2-shard displaced table saved from the
    single-device host process restores onto a 2-forced-device mesh (one
    shard per device via the stacked-HashMem specs) and answers the same
    probes bit-exactly through the sharded RLU path."""
    from repro.core import hashmap, rlu
    from model import mine_bucket_colliding_keys

    cfg = _displaced_cfg()
    rng = np.random.default_rng(3)
    keys = np.unique(rng.integers(1, 1 << 30, 64).astype(np.uint32))
    vals = (keys * 3 + 1).astype(np.uint32)
    hm = rlu.build_sharded(cfg, jnp.asarray(keys), jnp.asarray(vals), 2,
                           shard_by="highbits")
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, hm)

    # expected results computed in THIS process (host, 1 real device)
    qs = np.concatenate([keys, keys + 9_000_000]).astype(np.uint32)
    qs = qs[:(qs.size // 2) * 2]
    np.save(tmp_path / "queries.npy", qs)
    shards = [jax.tree.map(lambda x: x[d], hm) for d in range(2)]
    run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import Checkpointer
        from repro.core import hashmap, rlu
        from repro.distributed.sharding import named, stacked_hashmem_specs
        from repro.launch.mesh import make_serving_mesh
        from test_checkpoint import _displaced_cfg

        cfg = _displaced_cfg()
        mesh = make_serving_mesh(2)
        target = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[hashmap.create(cfg) for _ in range(2)])
        ck = Checkpointer({str(tmp_path)!r}, async_save=False)
        hm = ck.restore(1, target,
                        shardings=named(mesh, stacked_hashmem_specs(target)))
        # one shard per device along the model axis
        leaf = jax.tree_util.tree_leaves(hm)[0]
        assert len(leaf.sharding.device_set) == 2, leaf.sharding
        qs = np.load({str(tmp_path / 'queries.npy')!r})
        v, f = rlu.probe_sharded(mesh, hm, jnp.asarray(qs), cfg,
                                 shard_by="highbits")
        np.save({str(tmp_path / 'got_v.npy')!r}, np.asarray(v))
        np.save({str(tmp_path / 'got_f.npy')!r}, np.asarray(f))
        print("OK")
        """)
    got_v = np.load(tmp_path / "got_v.npy")
    got_f = np.load(tmp_path / "got_f.npy")
    # bit-compare against per-shard host probes at the owner of each query
    owner = np.asarray(rlu.owner_of(jnp.asarray(qs), cfg, 2,
                                    shard_by="highbits"))
    for d in range(2):
        m = owner == d
        if not m.any():
            continue
        ev, ef = hashmap.probe(shards[d], jnp.asarray(qs[m]))
        np.testing.assert_array_equal(got_v[m], np.asarray(ev))
        np.testing.assert_array_equal(got_f[m], np.asarray(ef))
    assert got_f[:keys.size].all() and not got_f[keys.size:].any()
