import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip_bitexact(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    ck.save(7, t)
    assert ck.latest_step() == 7
    got = ck.restore(7, jax.eval_shape(lambda: tree()))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(1, tree(1))
    ck.wait()
    got = ck.restore(1, jax.eval_shape(lambda: tree(1)))
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree(1)["a"]))


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, tree())
    d = tmp_path / "step_00000003"
    manifest = json.loads((d / "manifest.json").read_text())
    name = next(k for k, v in manifest["arrays"].items()
                if v["shape"] == [16, 8])
    fn = manifest["arrays"][name]["file"]
    arr = np.load(d / fn)
    arr[0, 0] += 1
    np.save(d / fn, arr)
    with pytest.raises(IOError, match="corruption"):
        ck.restore(3, jax.eval_shape(lambda: tree()))


def test_gc_keeps_last_three(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    for s in range(5):
        ck.save(s, {"x": jnp.zeros(3)})
    assert sorted(ck.all_steps()) == [2, 3, 4]


def test_atomicity_no_partial_dir(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, tree())
    assert not list(tmp_path.glob("tmp.*"))
