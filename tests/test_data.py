import numpy as np

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMData
from repro.data.kv_synth import kv_dataset, probe_set


def test_determinism_across_restarts():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 8, "train")
    d1 = SyntheticLMData(cfg, shape, seed=3)
    d2 = SyntheticLMData(cfg, shape, seed=3)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_shards_disjoint_and_deterministic():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 8, "train")
    sh0 = SyntheticLMData(cfg, shape, seed=3, shard_index=0, num_shards=2)
    sh1 = SyntheticLMData(cfg, shape, seed=3, shard_index=1, num_shards=2)
    b0, b1 = sh0.batch_at(5), sh1.batch_at(5)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 4, "train")
    b = SyntheticLMData(cfg, shape, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetch_iterator():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 32, 2, "train")
    data = SyntheticLMData(cfg, shape, seed=1)
    it = data.iterator(0)
    batches = [next(it) for _ in range(3)]
    data.close()
    np.testing.assert_array_equal(batches[2]["tokens"],
                                  data.batch_at(2)["tokens"])


def test_learnable_structure():
    """The injected grammar makes next-token partially predictable."""
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 256, 8, "train")
    b = SyntheticLMData(cfg, shape, seed=0).batch_at(0)
    t = b["tokens"]
    det = (3 * t[:, :-1] + 7) % cfg.vocab_size
    frac = (t[:, 1:] == det).mean()
    assert frac > 0.5


def test_kv_dataset_unique():
    keys, vals = kv_dataset(10_000, seed=0)
    assert len(np.unique(keys)) == 10_000
    q, idx = probe_set(keys, 0.1)
    assert len(q) == 1000
    assert np.isin(q, keys).all()
