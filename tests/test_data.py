import numpy as np

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMData
from repro.data.kv_synth import kv_dataset, probe_set


def test_determinism_across_restarts():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 8, "train")
    d1 = SyntheticLMData(cfg, shape, seed=3)
    d2 = SyntheticLMData(cfg, shape, seed=3)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_shards_disjoint_and_deterministic():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 8, "train")
    sh0 = SyntheticLMData(cfg, shape, seed=3, shard_index=0, num_shards=2)
    sh1 = SyntheticLMData(cfg, shape, seed=3, shard_index=1, num_shards=2)
    b0, b1 = sh0.batch_at(5), sh1.batch_at(5)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 4, "train")
    b = SyntheticLMData(cfg, shape, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetch_iterator():
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 32, 2, "train")
    data = SyntheticLMData(cfg, shape, seed=1)
    it = data.iterator(0)
    batches = [next(it) for _ in range(3)]
    data.close()
    np.testing.assert_array_equal(batches[2]["tokens"],
                                  data.batch_at(2)["tokens"])


def test_learnable_structure():
    """The injected grammar makes next-token partially predictable."""
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 256, 8, "train")
    b = SyntheticLMData(cfg, shape, seed=0).batch_at(0)
    t = b["tokens"]
    det = (3 * t[:, :-1] + 7) % cfg.vocab_size
    frac = (t[:, 1:] == det).mean()
    assert frac > 0.5


def test_kv_dataset_unique():
    keys, vals = kv_dataset(10_000, seed=0)
    assert len(np.unique(keys)) == 10_000
    q, idx = probe_set(keys, 0.1)
    assert len(q) == 1000
    assert np.isin(q, keys).all()


def test_zipfian_weights_shape_and_skew():
    from repro.data.kv_synth import zipfian_weights
    w = zipfian_weights(1000, theta=0.99)
    assert w.shape == (1000,)
    assert abs(w.sum() - 1.0) < 1e-9
    assert (np.diff(w) <= 0).all()               # monotone hot head
    assert w[0] / w[-1] > 100                    # real skew at theta=0.99
    u = zipfian_weights(1000, theta=0.0)         # theta=0 -> uniform
    assert np.allclose(u, 1 / 1000)


def test_ycsb_mix_catalog():
    from repro.data.kv_synth import ycsb_default_dist, ycsb_mix
    import pytest
    for wl in "ABCDEF":
        mix = ycsb_mix(wl)
        assert abs(sum(mix.values()) - 1.0) < 1e-9
        assert set(mix) <= {"read", "update", "insert", "scan", "rmw"}
    assert ycsb_mix("C") == {"read": 1.0}
    assert ycsb_mix("e")["scan"] == 0.95         # case-insensitive
    assert ycsb_default_dist("D") == "latest"
    with pytest.raises(KeyError):
        ycsb_mix("Z")


def test_zipfian_workload_stream():
    from repro.data.kv_synth import zipfian_workload
    ops = list(zipfian_workload(300, keyspace=64, seed=5))
    assert len(ops) == 300
    kinds = {op for op, _, _ in ops}
    assert kinds == {"insert", "delete", "probe"}
    for op, ks, vs in ops:
        assert ks.dtype == np.uint32 and (ks < np.uint32(0xFFFFFFF0)).all()
        assert (vs is None) == (op != "insert")
    # zipfian skew: the hottest key appears far more often than the median
    counts = {}
    for _, ks, _ in ops:
        for k in ks:
            counts[int(k)] = counts.get(int(k), 0) + 1
    c = sorted(counts.values(), reverse=True)
    assert c[0] > 4 * c[len(c) // 2]
    # deterministic for a fixed seed
    again = list(zipfian_workload(300, keyspace=64, seed=5))
    for (o1, k1, v1), (o2, k2, v2) in zip(ops, again):
        assert o1 == o2 and (k1 == k2).all()


def test_zipfian_workload_ycsb_mapping():
    from repro.data.kv_synth import zipfian_workload
    ops = [op for op, _, _ in zipfian_workload(400, keyspace=64,
                                               workload="B", seed=9)]
    frac_probe = ops.count("probe") / len(ops)
    assert 0.8 < frac_probe <= 1.0               # B is read-mostly
    assert ops.count("delete") < 0.15 * len(ops)
