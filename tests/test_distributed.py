"""Multi-device parity tests.  Each test runs in a SUBPROCESS with
--xla_force_host_platform_device_count=8 so the main pytest process keeps
the single real CPU device (assignment requirement)."""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_channel_parallel_probe_matches_single():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import HashMemConfig
        from repro.core import hashmap, rlu
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = HashMemConfig(num_buckets=32, slots_per_page=128,
                            overflow_pages=64, max_chain=4, backend="perf")
        rng = np.random.default_rng(2)
        keys = rng.choice(2**31, size=2000, replace=False).astype(np.uint32)
        vals = rng.integers(0, 2**31, size=2000).astype(np.uint32)
        hm_stacked = rlu.build_sharded(cfg, jnp.asarray(keys),
                                       jnp.asarray(vals), num_shards=4)
        q = np.concatenate([keys[:256],
                            (keys[:256].astype(np.uint64)+2**31).astype(np.uint32)])
        with mesh:
            v, f = rlu.probe_sharded(mesh, hm_stacked, jnp.asarray(q), cfg)
        v, f = np.asarray(v), np.asarray(f)
        assert f[:256].all() and (v[:256] == vals[:256]).all()
        assert not f[256:].any()
        # single-device reference
        hm = hashmap.build(cfg._replace(backend="ref") if hasattr(cfg, "_replace")
                           else cfg, jnp.asarray(keys), jnp.asarray(vals))
        v1, f1 = hashmap.probe(hm, jnp.asarray(q), backend="ref")
        assert (np.asarray(f1) == f).all()
        assert (np.asarray(v1)[f] == v[f]).all()
        print("OK")
        """)


def test_channel_parallel_probe_after_sharded_growth():
    """probe_sharded on the mesh AFTER rlu.insert_sharded forced synchronized
    shard growth (the grown stacked pytree must still shard/route/probe)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import HashMemConfig
        from repro.core import rlu
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = HashMemConfig(num_buckets=4, slots_per_page=32,
                            overflow_pages=4, max_chain=3, backend="perf",
                            auto_grow=True)
        rng = np.random.default_rng(17)
        k0 = rng.choice(2**30, 64, replace=False).astype(np.uint32)
        hm_stacked = rlu.build_sharded(cfg, jnp.asarray(k0),
                                       jnp.asarray(k0 * 2), num_shards=4)
        # way past per-shard capacity -> insert_sharded grows every shard
        k1 = np.setdiff1d(rng.choice(2**30, 1500, replace=False)
                          .astype(np.uint32), k0)
        hm_stacked, ok, cfg2 = rlu.insert_sharded(
            hm_stacked, jnp.asarray(k1), jnp.asarray(k1 * 2), cfg,
            num_shards=4)
        assert bool(jnp.all(ok))
        assert cfg2.num_buckets > cfg.num_buckets
        allk = np.concatenate([k0, k1])
        miss = (allk[:128].astype(np.uint64) + 2**31).astype(np.uint32)
        q = np.concatenate([allk, miss])
        q = q[: (q.size // 8) * 8]      # trims only trailing miss keys
        n_hit = allk.size
        with mesh:
            v, f = rlu.probe_sharded(mesh, hm_stacked, jnp.asarray(q), cfg2)
        v, f = np.asarray(v), np.asarray(f)
        assert f[:n_hit].all()
        assert (v[:n_hit] == q[:n_hit] * np.uint32(2)).all()
        assert not f[n_hit:].any()
        print("OK")
        """)


def test_sharded_train_step_matches_single_device():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.configs.base import OptimConfig, ShapeConfig
        from repro.data import SyntheticLMData
        from repro.distributed import steps as dsteps
        from repro.launch.mesh import make_mesh
        cfg = smoke_config("llama3-8b").replace(dtype="float32")
        oc = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        shape = ShapeConfig("t", 64, 8, "train")
        data = SyntheticLMData(cfg, shape, seed=0)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

        losses = {}
        for dims in [(1, 1), (2, 4), (4, 2)]:
            mesh = make_mesh(dims, ("data", "model"))
            _, jitted, pshard, oshard = dsteps.build_train_step(
                cfg, oc, mesh, seq_shard=True)
            params, opt = dsteps.init_train_state(cfg, oc, mesh,
                                                  jax.random.PRNGKey(0))
            p2, o2, m = jitted(batch)(params, opt, batch)
            losses[dims] = float(m["loss"])
        base = losses[(1, 1)]
        for dims, l in losses.items():
            assert abs(l - base) < 5e-4, (dims, l, base)
        print("OK", losses)
        """)


def test_multipod_mesh_train_step():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.configs.base import OptimConfig, ShapeConfig
        from repro.data import SyntheticLMData
        from repro.distributed import steps as dsteps
        from repro.launch.mesh import make_mesh
        cfg = smoke_config("olmoe-1b-7b").replace(dtype="float32")
        oc = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        shape = ShapeConfig("t", 64, 8, "train")
        batch = {k: jnp.asarray(v) for k, v in
                 SyntheticLMData(cfg, shape, seed=0).batch_at(0).items()}
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        _, jitted, _, _ = dsteps.build_train_step(cfg, oc, mesh)
        params, opt = dsteps.init_train_state(cfg, oc, mesh,
                                              jax.random.PRNGKey(0))
        p2, o2, m = jitted(batch)(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
        """)


def test_channel_parallel_serve_matches_single():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.launch.mesh import make_mesh
        from repro.launch.serve import serve
        cfg = smoke_config("llama3-8b").replace(dtype="float32")
        done1, _, _ = serve(cfg, make_mesh((1, 1), ("data", "model")),
                            batch=2, requests=3, max_new=4, horizon=64,
                            page_tokens=16, verbose=False, seed=1)
        done8, _, _ = serve(cfg, make_mesh((2, 4), ("data", "model")),
                            batch=2, requests=3, max_new=4, horizon=64,
                            page_tokens=16, verbose=False, seed=1)
        a = {r["id"]: r["out"] for r in done1}
        b = {r["id"]: r["out"] for r in done8}
        assert a == b, (a, b)
        print("OK")
        """)
