"""Dry-run machinery test on a reduced (2,2[,2]) mesh in a subprocess —
exercises the exact lower_cell/analyze path used for the production grid."""
import json
import os
import subprocess
import sys


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_dryrun(arch, shape, mesh_kind, probe, tmp_path, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_HOST_DEVICES"] = "8"
    env["REPRO_MESH"] = "2,2"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh_kind, "--probe", probe,
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=timeout)
    from repro.launch.dryrun import report_name
    name = report_name(arch, shape, mesh_kind, probe)
    report = tmp_path / name
    # check the exit code BEFORE reading the report so a crashed dry-run
    # surfaces its own traceback instead of a FileNotFoundError here
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert report.exists(), \
        f"dry-run wrote {[p.name for p in tmp_path.iterdir()]}, expected {name}"
    return json.loads(report.read_text())


def test_train_cell_lowers_and_reports(tmp_path):
    rec = run_dryrun("h2o-danube-1.8b", "train_4k", "single", "full", tmp_path)
    assert rec["ok"]
    assert rec["flops_per_device"] > 0
    assert rec["collectives"]["total_bytes"] > 0
    assert "temp_size_in_bytes" in rec


def test_decode_cell_lowers(tmp_path):
    rec = run_dryrun("h2o-danube-1.8b", "decode_32k", "single", "full",
                     tmp_path)
    assert rec["ok"]
    assert rec["n_pages"] >= 1


def test_multipod_cell_lowers(tmp_path):
    rec = run_dryrun("xlstm-1.3b", "train_4k", "multi", "full", tmp_path)
    assert rec["ok"]
    assert rec["mesh"] == {"pod": 2, "data": 2, "model": 2}


def test_probe_extrapolation_consistent(tmp_path):
    """unit2 flops > unit1 flops (the per-layer delta is positive)."""
    r1 = run_dryrun("h2o-danube-1.8b", "train_4k", "single", "unit1", tmp_path)
    r2 = run_dryrun("h2o-danube-1.8b", "train_4k", "single", "unit2", tmp_path)
    assert r1["ok"] and r2["ok"]
    assert r2["flops_per_device"] > r1["flops_per_device"] * 1.05
