"""Extendible (directory-based) resize: split/double invariants, the
four-backend differential, and the insert_auto grow-budget semantics.

The structural claim under test: with resize="extendible" an overflowing
GROUP splits alone (re-bucketing only its own live entries into one newly
allocated page region) and the directory doubles by pointer copy — every
other group's pages, chain links and directory entries are bit-identical
before and after, so probes of untouched keys cannot observe a resize.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.core.hashing import bits_used, hash_to_bucket

from model import DictModel, mine_bucket_colliding_keys


def _cfg(**kw):
    base = dict(num_buckets=8, slots_per_page=4, overflow_pages=120,
                max_chain=4, backend="ref", auto_grow=True,
                resize="extendible", max_load_factor=1.0)
    base.update(kw)
    return HashMemConfig(**base)


def _probe_all(hm, keys):
    vals, found = hashmap.probe(hm, jnp.asarray(keys, jnp.uint32))
    return np.asarray(vals), np.asarray(found)


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

def test_resize_knob_validation():
    with pytest.raises(ValueError, match="unknown resize"):
        hashmap.create(_cfg(resize="incremental"))
    with pytest.raises(ValueError, match="extendible"):
        hashmap.create(_cfg(displacement=True))
    with pytest.raises(ValueError, match="extendible"):
        hashmap.create(_cfg(stash_slots=32))
    with pytest.raises(ValueError, match="power-of-two"):
        hashmap.create(_cfg(num_buckets=6))
    # rebuild mode keeps accepting all of those shapes
    hashmap.create(_cfg(resize="rebuild", displacement=True,
                        fingerprint_bits=8, stash_slots=32,
                        slots_per_page=32, num_buckets=6))


# ---------------------------------------------------------------------------
# Directory doubling: pointer copy, shape-invariant, probe-invisible
# ---------------------------------------------------------------------------

def test_double_directory_is_pointer_copy():
    cfg = _cfg()
    keys = jnp.arange(1, 33, dtype=jnp.uint32)
    vals = keys * 3
    hm, ok = hashmap.insert(hashmap.create(cfg), keys, vals)
    assert bool(np.asarray(ok).all())

    hm2 = hashmap.double_directory(hm)
    assert hm2 is not None
    assert hm2.config.num_buckets == 2 * cfg.num_buckets
    # num_pages (and with it every store array shape) is INVARIANT
    assert hm2.config.num_pages == cfg.num_pages
    assert hm2.store.pool.shape == hm.store.pool.shape
    np.testing.assert_array_equal(
        np.asarray(hm2.bucket_head),
        np.concatenate([np.asarray(hm.bucket_head)] * 2))
    # local depths unchanged -> global depth grew past them
    st = hashmap.stats(hm2)
    assert st["global_depth"] == bits_used(cfg.num_buckets) + 1
    assert st["max_local_depth"] == bits_used(cfg.num_buckets)
    # probe-invisible: same values/found through the doubled directory
    v1, f1 = _probe_all(hm, keys)
    v2, f2 = _probe_all(hm2, keys)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(f1, f2)
    assert f2.all()


def test_double_directory_refuses_when_arena_too_small():
    # overflow arena cannot cede num_buckets pages of accounting
    hm = hashmap.create(_cfg(num_buckets=16, overflow_pages=8))
    assert hashmap.double_directory(hm) is None


# ---------------------------------------------------------------------------
# split_group: statuses and locality
# ---------------------------------------------------------------------------

def test_split_group_statuses_and_locality():
    cfg = _cfg(max_chain=2, overflow_pages=56)
    # every freshly created group sits at local depth == global depth
    hm = hashmap.create(cfg)
    hm1, status = hashmap.split_group(hm, 0)
    assert status == "need_double" and hm1 is hm

    # mine keys sharing one bucket mod 8 (but generically differing on the
    # next hash bit), overflow that group's chain, then split it
    keys = mine_bucket_colliding_keys(8, cfg.num_buckets, same_b2=False)
    vals = np.arange(1, 9, dtype=np.uint32) * 7
    hm, ok = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(vals))
    assert bool(np.asarray(ok).all())
    b0 = int(np.asarray(hash_to_bucket(jnp.asarray(keys), cfg.num_buckets,
                                       cfg.hash_fn, cfg.salt))[0])

    hm = hashmap.double_directory(hm)
    assert hm is not None
    heads_before = np.asarray(hm.bucket_head).copy()
    pool_before = np.asarray(hm.store.pool).copy()

    # the split may only touch the old chain's pages (cleared) and the pages
    # it allocates at the bump pointer — record both regions up front
    ld = bits_used(cfg.num_buckets)                 # pre-split local depth
    c = b0 & ((1 << ld) - 1)
    old_pages, p = [], int(heads_before[c])
    pn = np.asarray(hm.store.page_next)
    while p >= 0:
        old_pages.append(p)
        p = int(pn[p])
    top_before = int(hm.store.free_top)

    hm2, status = hashmap.split_group(hm, b0)
    assert status == "ok"
    # directory: exactly the aliases of the split group were repointed
    gd = bits_used(hm2.config.num_buckets)
    aliases = c + (np.arange(1 << (gd - ld)) << ld)
    untouched = np.setdiff1d(np.arange(hm2.config.num_buckets), aliases)
    np.testing.assert_array_equal(np.asarray(hm2.bucket_head)[untouched],
                                  heads_before[untouched])
    # both children report depth ld+1
    ch = np.asarray(hm2.bucket_head)[aliases]
    np.testing.assert_array_equal(
        np.asarray(hm2.store.local_depth)[ch], ld + 1)
    # every OTHER group's pages are bit-identical (split is LOCAL)
    touched = set(old_pages) | set(range(top_before,
                                         int(hm2.store.free_top)))
    other = np.setdiff1d(np.arange(cfg.num_pages),
                         np.asarray(sorted(touched)))
    np.testing.assert_array_equal(np.asarray(hm2.store.pool)[other],
                                  pool_before[other])
    # all entries survived the split with their values
    v, f = _probe_all(hm2, keys)
    assert f.all()
    np.testing.assert_array_equal(v, vals)
    st = hashmap.stats(hm2)
    assert st["min_local_depth"] == ld and st["max_local_depth"] == ld + 1


def test_split_group_stuck_full_and_rebuild_fallback():
    cfg = _cfg(max_chain=2, num_buckets=8, overflow_pages=56)
    # keys colliding mod 64 share every split bit up to depth 6: a depth-3
    # split routes ALL of them to one child
    keys = mine_bucket_colliding_keys(8, 64, same_b2=False)
    hm, ok = hashmap.insert(hashmap.create(cfg), jnp.asarray(keys),
                            jnp.arange(1, 9, dtype=jnp.uint32))
    assert bool(np.asarray(ok).all())
    b0 = int(np.asarray(hash_to_bucket(jnp.asarray(keys), cfg.num_buckets,
                                       cfg.hash_fn, cfg.salt))[0])
    hm = hashmap.double_directory(hm)
    assert hm is not None

    # with the chain bound tightened under the live population, the one
    # child cannot exist -> "stuck" (pre-flight refuses, no mutation)
    tight = hashmap.HashMem(
        store=hm.store, bucket_head=hm.bucket_head,
        config=dataclasses.replace(hm.config, max_chain=1))
    _, status = hashmap.split_group(tight, b0)
    assert status == "stuck"

    # an exhausted bump arena refuses the split outright -> "full"
    full = hashmap.HashMem(
        store=dataclasses.replace(
            hm.store, free_top=jnp.asarray(hm.config.num_pages, jnp.int32)),
        bucket_head=hm.bucket_head, config=hm.config)
    _, status = hashmap.split_group(full, b0)
    assert status == "full"

    # grow_extendible on the full table falls back to a genuine rebuild
    # (the only path that adds pages) and still answers every probe
    hm2, how = hashmap.grow_extendible(full, b0)
    assert how == "rebuild"
    assert hm2.config.num_pages > hm.config.num_pages
    _, f = _probe_all(hm2, keys)
    assert f.all()


# ---------------------------------------------------------------------------
# insert_extendible: splits instead of rebuilds; duplicate FIFO survives
# ---------------------------------------------------------------------------

def test_insert_extendible_splits_not_rebuilds():
    cfg = _cfg(max_chain=2, slots_per_page=4, num_buckets=8,
               overflow_pages=120)
    keys = mine_bucket_colliding_keys(24, cfg.num_buckets, same_b2=False)
    vals = np.arange(1, 25, dtype=np.uint32)
    events: dict = {}
    hm, ok = hashmap.insert_extendible(
        hashmap.create(cfg), jnp.asarray(keys), jnp.asarray(vals),
        events=events)
    assert bool(np.asarray(ok).all())
    assert events.get("splits", 0) >= 1
    assert events.get("rebuilds", 0) == 0
    assert hm.config.num_pages == cfg.num_pages        # never rebuilt
    v, f = _probe_all(hm, keys)
    assert f.all()
    np.testing.assert_array_equal(v, vals)
    st = hashmap.stats(hm)
    assert st["max_local_depth"] > bits_used(cfg.num_buckets)


def test_duplicate_fifo_order_survives_splits():
    cfg = _cfg(max_chain=2, num_buckets=8, overflow_pages=120)
    keys = mine_bucket_colliding_keys(20, cfg.num_buckets, same_b2=False)
    dup = int(keys[0])
    hm = hashmap.create(cfg)
    # oldest duplicate first, then force splits over the same group
    hm, ok = hashmap.insert(hm, jnp.asarray([dup], jnp.uint32),
                            jnp.asarray([111], jnp.uint32))
    assert bool(np.asarray(ok).all())
    hm, ok = hashmap.insert_extendible(
        hm, jnp.asarray(keys[1:]), jnp.arange(1, 20, dtype=jnp.uint32))
    assert bool(np.asarray(ok).all())
    hm, ok = hashmap.insert_extendible(
        hm, jnp.asarray([dup], jnp.uint32), jnp.asarray([222], jnp.uint32))
    assert bool(np.asarray(ok).all())
    v, f = _probe_all(hm, [dup])
    assert f[0] and v[0] == 111                      # oldest wins
    hm, found = hashmap.delete(hm, jnp.asarray([dup], jnp.uint32))
    assert bool(np.asarray(found)[0])
    v, f = _probe_all(hm, [dup])
    assert f[0] and v[0] == 222                      # FIFO successor


def test_rebuild_under_extendible_resets_directory_and_reclaims():
    cfg = _cfg(max_chain=2, num_buckets=8, overflow_pages=120)
    keys = mine_bucket_colliding_keys(24, cfg.num_buckets, same_b2=False)
    hm, ok = hashmap.insert_extendible(
        hashmap.create(cfg), jnp.asarray(keys),
        jnp.arange(1, 25, dtype=jnp.uint32))
    assert bool(np.asarray(ok).all())
    hm2 = hashmap.compact(hm)
    st = hashmap.stats(hm2)
    # directory flat again: every group back at the global depth
    assert st["min_local_depth"] == st["max_local_depth"] \
        == st["global_depth"]
    # pages leaked by the splits' bump allocation were reclaimed: the bump
    # pointer sits exactly at directory + strictly-needed overflow
    cfg2 = hm2.config
    overflow_needed = int(np.maximum(st["chain_lengths"] - 1, 0).sum())
    assert st["free_pages"] == \
        cfg2.num_pages - cfg2.num_buckets - overflow_needed
    _, f = _probe_all(hm2, keys)
    assert f.all()


# ---------------------------------------------------------------------------
# Four-backend differential: churn through splits/doublings vs DictModel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "perf", "area", "bitserial"])
def test_extendible_churn_differential(backend):
    # bitserial packs bit-planes 32-slots-per-word: S must be a multiple of
    # 32, so it gets a 1-page chain bound to keep group capacity small
    # enough that the mined colliders below still force splits
    S, mc = (32, 1) if backend == "bitserial" else (4, 3)
    cfg = _cfg(backend=backend, slots_per_page=S, num_buckets=8,
               overflow_pages=248, max_chain=mc)
    colliders = mine_bucket_colliding_keys(48, cfg.num_buckets,
                                           same_b2=False)
    rng = np.random.default_rng(17)
    hm = hashmap.create(cfg)
    model = DictModel()
    events: dict = {}
    for step in range(8):
        # uniform churn plus 6 mined same-group keys per step: the hot
        # group overflows its chain bound and must split mid-churn
        ins = np.concatenate([
            rng.integers(1, 4000, size=12, dtype=np.uint32),
            colliders[6 * step:6 * (step + 1)]])
        vals = rng.integers(1, 2**20, size=ins.size, dtype=np.uint32)
        hm, ok = hashmap.insert_auto(hm, jnp.asarray(ins), jnp.asarray(vals),
                                     events=events)
        model.insert(ins, vals, np.asarray(ok))
        dels = rng.integers(1, 4000, size=4, dtype=np.uint32)
        hm, found = hashmap.delete(hm, jnp.asarray(dels))
        exp_found = model.delete(dels)
        np.testing.assert_array_equal(np.asarray(found), exp_found)
        qs = np.concatenate([ins[:8], dels,
                             rng.integers(1, 4000, size=6, dtype=np.uint32)])
        v, f = _probe_all(hm, qs)
        ev, ef = model.probe(qs)
        np.testing.assert_array_equal(f, ef)
        np.testing.assert_array_equal(v[f], np.asarray(ev)[f])
    assert events.get("splits", 0) >= 1
    assert events.get("rebuilds", 0) == 0, \
        "extendible churn should repair by splitting, not rebuilding"


# ---------------------------------------------------------------------------
# Satellite 3: insert_auto draws proactive and reactive grows from SEPARATE
# budgets — a load-factor doubling must not starve the reactive repair
# ---------------------------------------------------------------------------

def test_insert_auto_separate_proactive_reactive_budgets():
    # identity hash for exact bucket control: bucket = key % num_buckets
    cfg = HashMemConfig(num_buckets=4, slots_per_page=4, overflow_pages=4,
                        max_chain=1, backend="ref", auto_grow=True,
                        hash_fn="identity", max_load_factor=0.5)
    hm = hashmap.create(cfg)
    # fill to 14/32 live — under the 0.5 load bar, spread across buckets
    pre = np.arange(14, dtype=np.uint32)
    hm, ok = hashmap.insert_auto(hm, jnp.asarray(pre),
                                 jnp.asarray(pre + 100))
    assert bool(np.asarray(ok).all())
    assert hm.config.num_buckets == 4                 # no grow yet

    # 5 keys congruent mod 16: the batch (a) crosses the 0.5 load bar ->
    # exactly ONE proactive doubling (nb 4 -> 8), then (b) all 5 land in one
    # depth-3 bucket of capacity 4 -> TWO reactive doublings (nb 8 -> 32)
    # before they separate mod 32.  A shared max_grows=2 budget would refuse
    # the last key; separate budgets repair it.
    batch = np.asarray([15, 31, 47, 63, 79], np.uint32)
    events: dict = {}
    hm, ok = hashmap.insert_auto(hm, jnp.asarray(batch),
                                 jnp.asarray(batch * 2), max_grows=2,
                                 events=events)
    assert bool(np.asarray(ok).all()), \
        "reactive repair was starved by the proactive grow budget"
    assert hm.config.num_buckets == 32
    assert events.get("rebuilds", 0) == 3             # 1 proactive + 2 reactive
    v, f = _probe_all(hm, np.concatenate([pre, batch]))
    assert f.all()
    np.testing.assert_array_equal(
        v, np.concatenate([pre + 100, batch * 2]))


def test_insert_auto_reactive_budget_still_bounds():
    # with max_grows=0 the reactive loop must refuse rather than spin
    cfg = HashMemConfig(num_buckets=4, slots_per_page=2, overflow_pages=4,
                        max_chain=1, backend="ref", auto_grow=True,
                        hash_fn="identity", max_load_factor=1.0)
    batch = np.asarray([3, 7, 11], np.uint32)          # all bucket 3, cap 2
    hm, ok = hashmap.insert_auto(hashmap.create(cfg), jnp.asarray(batch),
                                 jnp.asarray(batch), max_grows=0)
    ok = np.asarray(ok)
    assert ok.sum() == 2                            # page holds 2 of the 3
    assert hm.config.num_buckets == 4               # no grow happened
