import numpy as np
import pytest

from repro.distributed.compression import (Int8ErrorFeedback, compress_tree)
from repro.distributed.fault_tolerance import (
    FailureInjector, InjectedFailure, RestartPolicy, StragglerMonitor)


def test_injector_fires_once():
    inj = FailureInjector((3,))
    inj.check(2)
    with pytest.raises(InjectedFailure):
        inj.check(3)
    inj.check(3)  # second pass (post-restart) does not re-fire


def test_restart_policy_gives_up():
    pol = RestartPolicy(max_restarts=2)
    assert pol.on_failure(RuntimeError())
    assert pol.on_failure(RuntimeError())
    assert not pol.on_failure(RuntimeError())


def test_straggler_detection():
    mon = StragglerMonitor(factor=3.0, warmup=3)
    for s in range(6):
        assert not mon.observe(s, 0.1)
    assert mon.observe(6, 1.0)          # 10x median
    assert mon.backup_runs == 1
    assert not mon.observe(7, 0.12)


def test_bf16_compression_roundtrip_small_error():
    import jax.numpy as jnp
    g = {"w": jnp.linspace(-1, 1, 101, dtype=jnp.float32)}
    out = compress_tree(g, "bf16")
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err < 1e-2


def test_int8_error_feedback_converges():
    """EF-SGD on a quadratic: with error feedback the quantization bias
    vanishes; without it, aggressive quantization stalls progress."""
    import jax.numpy as jnp
    target = jnp.asarray([0.3, -0.7, 0.01])
    ef = Int8ErrorFeedback()

    def run(use_ef, steps=300, lr=0.05):
        w = jnp.zeros(3)
        err = ef.init({"g": w})
        for _ in range(steps):
            g = {"g": 2 * (w - target)}
            if use_ef:
                q, err = ef.apply(g, err)
            else:
                q = compress_tree(g, "int8")
            w = w - lr * q["g"]
        return float(jnp.max(jnp.abs(w - target)))

    assert run(True) < 0.02
