import numpy as np
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.distributed.compression import (Int8ErrorFeedback, compress_tree)
from repro.distributed.fault_tolerance import (
    FailureInjector, InjectedFailure, RestartPolicy, StragglerMonitor)
from repro.serving import Request, ServingEngine


def test_injector_fires_once():
    inj = FailureInjector((3,))
    inj.check(2)
    with pytest.raises(InjectedFailure):
        inj.check(3)
    inj.check(3)  # second pass (post-restart) does not re-fire


def test_restart_policy_gives_up():
    pol = RestartPolicy(max_restarts=2)
    assert pol.on_failure(RuntimeError())
    assert pol.on_failure(RuntimeError())
    assert not pol.on_failure(RuntimeError())


def test_straggler_detection():
    mon = StragglerMonitor(factor=3.0, warmup=3)
    for s in range(6):
        assert not mon.observe(s, 0.1)
    assert mon.observe(6, 1.0)          # 10x median
    assert mon.backup_runs == 1
    assert not mon.observe(7, 0.12)


# ---------------------------------------------------------------------------
# Serving-engine fault injection (host shards; the mesh variants run in
# tests/test_serving_sharded.py subprocesses)
# ---------------------------------------------------------------------------

def _eng(**kw):
    kw.setdefault("max_slots", 4)
    return ServingEngine(HashMemConfig(num_buckets=16, slots_per_page=8,
                                       overflow_pages=32, max_chain=4,
                                       backend="ref",
                                       compact_tombstone_frac=0.0), **kw)


def test_kill_between_pipelined_ticks_reclaims_slot():
    """FailureInjector-driven kill between pipelined ticks: the victim's
    in-flight ops complete, its remaining ops never run, the slot is
    immediately reusable, and tombstone/compaction accounting still
    reclaims the victim's dead entries."""
    eng = _eng(pipeline_depth=2, compact_every=4,
               record_schedule=True)
    eng.preload(np.arange(8, dtype=np.uint32), np.arange(8, dtype=np.uint32))
    victim = Request(ops=[("insert", 100, 1), ("delete", 100),
                          ("insert", 101, 2), ("insert", 102, 3)])
    eng.submit(victim)
    eng.submit_all([Request(ops=[("read", k)] * 3) for k in range(3)])
    backlog = Request(ops=[("read", 0)])

    inj = FailureInjector(fail_at_steps=(2,))
    killed_at = -1
    while not eng.pool.idle() or eng._inflight:
        try:
            inj.check(eng.ticks)
        except InjectedFailure:
            assert eng._inflight, "no in-flight tick at the kill point"
            assert eng.kill(victim)
            killed_at = eng.ticks
            assert eng.submit(backlog) == "admitted"   # slot reclaimed NOW
        if eng.pool.idle():
            eng.flush()
        else:
            eng.tick()
    assert killed_at == 2 and victim.killed
    assert victim.cursor == 2                   # insert100, delete100 issued
    assert backlog.done()
    # page reclamation: the victim's tombstone is compacted away on the
    # tick clock even though the victim never completed
    eng.submit_all([Request(ops=[("read", k)] * 4) for k in range(3)])
    eng.run()
    st = hashmap.stats(eng.shards[0])
    assert st["tombstones"] == 0 and eng.compact_events >= 1
    # table holds exactly what actually executed
    v, f = hashmap.probe(eng.shards[0],
                         np.asarray([100, 101, 102], np.uint32))
    assert not bool(np.asarray(f).any()), "un-issued ops leaked into table"


def test_forced_grow_during_pipelined_window_host():
    """Arena exhaustion mid-pipeline (deferred PR_ERROR at drain): growth
    repairs the refused inserts, nothing is lost or duplicated, and the
    pipelined run equals the unpipelined one."""
    cfg = HashMemConfig(num_buckets=2, slots_per_page=4, overflow_pages=4,
                        max_chain=2, backend="ref", auto_grow=True)
    keys = np.arange(64, dtype=np.uint32)

    def run(depth):
        eng = ServingEngine(cfg, max_slots=8, pipeline_depth=depth)
        reqs = [Request(ops=[("insert", int(k), int(k) * 3)])
                for k in keys]
        eng.submit_all(reqs)
        eng.run()
        return eng, [r.results for r in reqs]

    e1, r1 = run(1)
    e2, r2 = run(2)
    assert r2 == r1
    assert e2.grow_events >= 1
    for eng in (e1, e2):
        st = hashmap.stats(eng.shards[0])
        assert sum(hashmap.stats(hm)["live_entries"]
                   for hm in eng.shards) == 64, "grow lost keys"
        v, f = hashmap.probe(eng.shards[0], keys)
        assert bool(np.asarray(f).all())
        assert (np.asarray(v) == keys * 3).all()


def test_bf16_compression_roundtrip_small_error():
    import jax.numpy as jnp
    g = {"w": jnp.linspace(-1, 1, 101, dtype=jnp.float32)}
    out = compress_tree(g, "bf16")
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err < 1e-2


def test_int8_error_feedback_converges():
    """EF-SGD on a quadratic: with error feedback the quantization bias
    vanishes; without it, aggressive quantization stalls progress."""
    import jax.numpy as jnp
    target = jnp.asarray([0.3, -0.7, 0.01])
    ef = Int8ErrorFeedback()

    def run(use_ef, steps=300, lr=0.05):
        w = jnp.zeros(3)
        err = ef.init({"g": w})
        for _ in range(steps):
            g = {"g": 2 * (w - target)}
            if use_ef:
                q, err = ef.apply(g, err)
            else:
                q = compress_tree(g, "int8")
            w = w - lr * q["g"]
        return float(jnp.max(jnp.abs(w - target)))

    assert run(True) < 0.02
