import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing


def test_fmix_determinism_and_range():
    keys = jnp.arange(10_000, dtype=jnp.uint32)
    h1 = hashing.murmur3_fmix(keys)
    h2 = hashing.murmur3_fmix(keys)
    assert h1.dtype == jnp.uint32
    assert bool(jnp.all(h1 == h2))


def test_fmix_avalanche():
    """Flipping one input bit flips ~half the output bits."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**31, 2048).astype(np.uint32)
    h0 = np.asarray(hashing.murmur3_fmix(jnp.asarray(keys)))
    flipped = keys ^ np.uint32(1 << 7)
    h1 = np.asarray(hashing.murmur3_fmix(jnp.asarray(flipped)))
    diff = np.unpackbits((h0 ^ h1).view(np.uint8)).mean() * 32
    assert 12 < diff < 20  # ideal 16


@pytest.mark.parametrize("fn", list(hashing.HASH_FNS))
def test_bucket_range(fn):
    keys = jnp.arange(5000, dtype=jnp.uint32) * jnp.uint32(2654435761)
    b = hashing.hash_to_bucket(keys, 127, fn)
    assert int(b.min()) >= 0 and int(b.max()) < 127


def test_bucket_balance_murmur():
    """Murmur buckets are near-uniform (paper §6 'Hash Function' goal)."""
    keys = jnp.arange(100_000, dtype=jnp.uint32)
    b = np.asarray(hashing.hash_to_bucket(keys, 256))
    counts = np.bincount(b, minlength=256)
    assert counts.std() / counts.mean() < 0.12
