import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing


def test_fmix_determinism_and_range():
    keys = jnp.arange(10_000, dtype=jnp.uint32)
    h1 = hashing.murmur3_fmix(keys)
    h2 = hashing.murmur3_fmix(keys)
    assert h1.dtype == jnp.uint32
    assert bool(jnp.all(h1 == h2))


def test_fmix_avalanche():
    """Flipping one input bit flips ~half the output bits."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**31, 2048).astype(np.uint32)
    h0 = np.asarray(hashing.murmur3_fmix(jnp.asarray(keys)))
    flipped = keys ^ np.uint32(1 << 7)
    h1 = np.asarray(hashing.murmur3_fmix(jnp.asarray(flipped)))
    diff = np.unpackbits((h0 ^ h1).view(np.uint8)).mean() * 32
    assert 12 < diff < 20  # ideal 16


@pytest.mark.parametrize("fn", list(hashing.HASH_FNS))
def test_bucket_range(fn):
    keys = jnp.arange(5000, dtype=jnp.uint32) * jnp.uint32(2654435761)
    b = hashing.hash_to_bucket(keys, 127, fn)
    assert int(b.min()) >= 0 and int(b.max()) < 127


def test_bucket_balance_murmur():
    """Murmur buckets are near-uniform (paper §6 'Hash Function' goal)."""
    keys = jnp.arange(100_000, dtype=jnp.uint32)
    b = np.asarray(hashing.hash_to_bucket(keys, 256))
    counts = np.bincount(b, minlength=256)
    assert counts.std() / counts.mean() < 0.12


@pytest.mark.parametrize("fn", list(hashing.HASH_FNS))
@pytest.mark.parametrize("shard_by", ["mod", "highbits"])
def test_owner_of_np_mirrors_jnp_router(fn, shard_by):
    """rlu.owner_of_np hand-duplicates the hash mixers in numpy (the host
    partitioning / accounting path must not touch the device per phase);
    pin it bit-for-bit against the jnp router for every hash fn, router,
    and a range of shard counts — a drifted constant or shift in either
    copy silently routes keys to the wrong shard."""
    import dataclasses
    from repro.configs.base import HashMemConfig
    from repro.core import rlu

    cfg = dataclasses.replace(HashMemConfig(), hash_fn=fn)
    rng = np.random.default_rng(5)
    keys = np.concatenate([
        rng.integers(0, 2**32 - 2, 4096, dtype=np.int64).astype(np.uint32),
        np.asarray([0, 1, 0xFFFFFFF0, 0xFFFFFFFD], np.uint32)])
    for num_shards in (1, 2, 3, 4, 7, 8):
        o_np = rlu.owner_of_np(keys, cfg, num_shards, shard_by)
        o_j = np.asarray(rlu.owner_of(jnp.asarray(keys), cfg, num_shards,
                                      shard_by))
        assert (o_np == o_j).all(), (fn, shard_by, num_shards)
        assert o_np.min() >= 0 and o_np.max() < num_shards
