"""Property-based tests of the HashMem structure invariants.

``hypothesis`` is a dev-only dependency: when it is missing the
property-based tests skip (collection must never hard-fail) and the
``test_fallback_*`` tests below cover the same invariants on fixed
randomized inputs.
"""
import importlib.util

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    keys_strategy = st.lists(
        st.integers(min_value=0, max_value=2**31 - 1),
        min_size=1, max_size=300, unique=True)
else:  # no-op decorators so the @given tests still collect (as skips)
    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(**kw):
        return _skip

    def settings(**kw):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _StrategyStub()
    keys_strategy = None

CFG = HashMemConfig(num_buckets=16, slots_per_page=32, overflow_pages=96,
                    max_chain=6, backend="ref")


@settings(max_examples=25, deadline=None)
@given(keys=keys_strategy, salt=st.integers(0, 2**31))
def test_build_probe_roundtrip(keys, salt):
    keys = np.asarray(keys, np.uint32)
    vals = (keys * np.uint32(2654435761)) ^ np.uint32(salt)
    hm = hashmap.build(CFG, jnp.asarray(keys), jnp.asarray(vals))
    v, f = hashmap.probe(hm, jnp.asarray(keys))
    assert bool(jnp.all(f))
    assert bool(jnp.all(v == jnp.asarray(vals)))
    # keys not inserted are not found
    miss = keys.astype(np.uint64) + 2**31
    miss = miss[miss < 0xFFFFFFF0].astype(np.uint32)
    miss = np.setdiff1d(miss, keys)
    if miss.size:
        v2, f2 = hashmap.probe(hm, jnp.asarray(miss))
        assert not bool(jnp.any(f2))


@settings(max_examples=15, deadline=None)
@given(keys=keys_strategy, n_del=st.integers(0, 50))
def test_delete_semantics(keys, n_del):
    keys = np.asarray(keys, np.uint32)
    vals = keys + np.uint32(1)
    hm = hashmap.build(CFG, jnp.asarray(keys), jnp.asarray(vals))
    dels = keys[:min(n_del, len(keys))]
    hm, found = hashmap.delete(hm, jnp.asarray(dels))
    assert bool(jnp.all(found)) or dels.size == 0
    if dels.size:
        _, f = hashmap.probe(hm, jnp.asarray(dels))
        assert not bool(jnp.any(f))
    rest = keys[min(n_del, len(keys)):]
    if rest.size:
        v, f = hashmap.probe(hm, jnp.asarray(rest))
        assert bool(jnp.all(f)) and bool(jnp.all(v == jnp.asarray(rest + 1)))


@settings(max_examples=15, deadline=None)
@given(keys=keys_strategy)
def test_chain_structure_invariants(keys, ):
    keys = np.asarray(keys, np.uint32)
    hm = hashmap.build(CFG, jnp.asarray(keys), jnp.asarray(keys))
    nxt = np.asarray(hm.page_next)
    fill = np.asarray(hm.page_fill)
    # acyclic chains, depth bounded
    for b in range(CFG.num_buckets):
        seen = set()
        p = int(np.asarray(hm.bucket_head)[b])
        while p >= 0:
            assert p not in seen, "cycle in page chain"
            seen.add(p)
            p = int(nxt[p])
        assert len(seen) <= CFG.max_chain
    # live entries == inserted count
    st_ = hashmap.stats(hm)
    assert st_["live_entries"] == len(keys)
    # fill counts match non-empty slots
    kp = np.asarray(hm.key_pages)
    for page in range(CFG.num_pages):
        used = int((kp[page] != np.uint32(0xFFFFFFFF)).sum())
        assert used == fill[page]


def test_adversarial_single_bucket():
    """All keys forced into one bucket (identity hash, same residue):
    the paper's over-utilized bucket case -> overflow chain."""
    cfg = HashMemConfig(num_buckets=4, slots_per_page=32, overflow_pages=16,
                        max_chain=6, hash_fn="identity", backend="ref")
    keys = (np.arange(100, dtype=np.uint32) * 4 + 1)  # all bucket 1
    hm = hashmap.build(cfg, jnp.asarray(keys), jnp.asarray(keys * 7))
    st_ = hashmap.stats(hm)
    assert st_["max_chain"] == 4  # ceil(100/32)
    v, f = hashmap.probe(hm, jnp.asarray(keys))
    assert bool(jnp.all(f)) and bool(jnp.all(v == jnp.asarray(keys * 7)))


def test_insert_overflow_allocates_pages():
    cfg = HashMemConfig(num_buckets=2, slots_per_page=32, overflow_pages=8,
                        max_chain=4, hash_fn="identity", backend="ref")
    hm = hashmap.create(cfg)
    keys = np.arange(0, 120, 2, dtype=np.uint32)  # bucket 0 only
    hm, ok = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(keys))
    assert bool(jnp.all(ok))
    assert int(hm.free_top) == 2 + 1  # one overflow page allocated (60 keys)
    v, f = hashmap.probe(hm, jnp.asarray(keys))
    assert bool(jnp.all(f))


def test_insert_arena_exhaustion_returns_error():
    cfg = HashMemConfig(num_buckets=1, slots_per_page=32, overflow_pages=1,
                        max_chain=8, hash_fn="identity", backend="ref")
    hm = hashmap.create(cfg)
    keys = np.arange(100, dtype=np.uint32)
    hm, ok = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(keys))
    ok = np.asarray(ok)
    assert ok[:64].all()          # 2 pages x 32 slots
    assert not ok[64:].any()      # pim_malloc PR_ERROR past capacity


def test_tombstones_not_reused():
    """Paper §2.5: deletion wastes space; inserts append at the chain tail."""
    cfg = HashMemConfig(num_buckets=1, slots_per_page=32, overflow_pages=4,
                        max_chain=4, hash_fn="identity", backend="ref")
    hm = hashmap.create(cfg)
    k1 = np.arange(10, dtype=np.uint32)
    hm, _ = hashmap.insert(hm, jnp.asarray(k1), jnp.asarray(k1))
    hm, _ = hashmap.delete(hm, jnp.asarray(k1[:5]))
    assert hashmap.stats(hm)["tombstones"] == 5
    k2 = np.arange(100, 105, dtype=np.uint32)
    hm, ok = hashmap.insert(hm, jnp.asarray(k2), jnp.asarray(k2))
    assert bool(jnp.all(ok))
    assert hashmap.stats(hm)["tombstones"] == 5  # not reclaimed
    v, f = hashmap.probe(hm, jnp.asarray(k2))
    assert bool(jnp.all(f))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fallback_build_probe_delete_roundtrip(seed):
    """Non-hypothesis coverage of the @given invariants above (runs always)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 300))
    keys = rng.choice(2**31, n, replace=False).astype(np.uint32)
    vals = (keys * np.uint32(2654435761)) ^ np.uint32(seed)
    hm = hashmap.build(CFG, jnp.asarray(keys), jnp.asarray(vals))
    v, f = hashmap.probe(hm, jnp.asarray(keys))
    assert bool(jnp.all(f)) and bool(jnp.all(v == jnp.asarray(vals)))
    miss = keys.astype(np.uint64) + 2**31
    miss = np.setdiff1d(miss[miss < 0xFFFFFFF0].astype(np.uint32), keys)
    if miss.size:
        _, f2 = hashmap.probe(hm, jnp.asarray(miss))
        assert not bool(jnp.any(f2))
    # delete half, probe both halves
    dels = keys[: n // 2]
    hm, found = hashmap.delete(hm, jnp.asarray(dels))
    assert dels.size == 0 or bool(jnp.all(found))
    if dels.size:
        _, f3 = hashmap.probe(hm, jnp.asarray(dels))
        assert not bool(jnp.any(f3))
    rest, rvals = keys[n // 2:], vals[n // 2:]
    if rest.size:
        v4, f4 = hashmap.probe(hm, jnp.asarray(rest))
        assert bool(jnp.all(f4)) and bool(jnp.all(v4 == jnp.asarray(rvals)))


def test_fallback_chain_structure_invariants():
    rng = np.random.default_rng(11)
    keys = rng.choice(2**31, 250, replace=False).astype(np.uint32)
    hm = hashmap.build(CFG, jnp.asarray(keys), jnp.asarray(keys))
    nxt = np.asarray(hm.page_next)
    fill = np.asarray(hm.page_fill)
    for b in range(CFG.num_buckets):
        seen = set()
        p = int(np.asarray(hm.bucket_head)[b])
        while p >= 0:
            assert p not in seen, "cycle in page chain"
            seen.add(p)
            p = int(nxt[p])
        assert len(seen) <= CFG.max_chain
    st_ = hashmap.stats(hm)
    assert st_["live_entries"] == len(keys)
    kp = np.asarray(hm.key_pages)
    for page in range(CFG.num_pages):
        assert int((kp[page] != np.uint32(0xFFFFFFFF)).sum()) == fill[page]


@pytest.mark.parametrize("backend", ["ref", "perf", "area", "bitserial"])
def test_backends_agree(backend):
    cfg = HashMemConfig(num_buckets=8, slots_per_page=128, overflow_pages=32,
                        max_chain=5, backend=backend)
    rng = np.random.default_rng(7)
    keys = rng.choice(2**31, 2000, replace=False).astype(np.uint32)
    vals = rng.integers(0, 2**31, 2000).astype(np.uint32)
    hm = hashmap.build(cfg, jnp.asarray(keys), jnp.asarray(vals))
    q = np.concatenate([keys[:200], (keys[:100] + np.uint32(2**31))])
    v, f = hashmap.probe(hm, jnp.asarray(q))
    assert bool(jnp.all(f[:200])) and not bool(jnp.any(f[200:]))
    assert bool(jnp.all(v[:200] == jnp.asarray(vals[:200])))
