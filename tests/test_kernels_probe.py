"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
oracle (kernels/ref.py) on identical interleaved page pools, including
missing keys, tombstones and chain padding.  All kernels consume the unified
PageStore (P, S, 2) pool — one fetched row per chain step carries keys and
values."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout
from repro.kernels import ref
from repro.kernels.probe_area import probe_pages_area
from repro.kernels.probe_bitserial import probe_pages_bitserial
from repro.kernels.probe_perf import probe_pages_perf


def make_pool(rng, P, S, key_bits=32, fill=0.7, tombstones=0.05):
    max_key = min(2**key_bits - 2, 0xFFFFFFF0)
    kp = np.full((P, S), 0xFFFFFFFF, np.uint32)
    vp = np.zeros((P, S), np.uint32)
    n = int(P * S * fill)
    if n <= max_key:
        keys = rng.choice(max_key, size=n, replace=False).astype(np.uint32)
        vals = rng.integers(0, 2**31, n).astype(np.uint32)
    else:
        # tiny key spaces (4/8-bit): duplicates allowed; value = f(key) so
        # first-match semantics yield identical values for any copy
        keys = rng.integers(0, max_key, n).astype(np.uint32)
        vals = (keys * np.uint32(2654435761)) >> np.uint32(3)
    pos = rng.choice(P * S, size=n, replace=False)
    kp.reshape(-1)[pos] = keys
    vp.reshape(-1)[pos] = vals
    # tombstones
    tpos = rng.choice(pos, size=int(n * tombstones), replace=False)
    kp.reshape(-1)[tpos] = 0xFFFFFFFE
    live = np.setdiff1d(pos, tpos)
    return kp, vp, live


def make_queries(rng, kp, vp, live, Q, C, P, key_bits=32):
    flat_k = kp.reshape(-1)
    hit = rng.choice(live, size=Q // 2)
    hit_keys = flat_k[hit]
    hit_pages = (hit // kp.shape[1]).astype(np.int32)
    max_key = min(2**key_bits - 2, 0xFFFFFFF0)
    missing = rng.choice(max_key, size=Q - Q // 2).astype(np.uint32)
    missing = np.where(np.isin(missing, flat_k),
                       np.uint32(max_key - 1), missing)
    queries = np.concatenate([hit_keys, missing])
    pages = np.full((Q, C), -1, np.int32)
    for i in range(Q // 2):
        pages[i, rng.integers(0, C)] = hit_pages[i]
        extra = rng.integers(0, P, C)
        m = rng.random(C) < 0.4
        pages[i] = np.where((pages[i] < 0) & m, extra, pages[i])
    for i in range(Q // 2, Q):
        pages[i] = rng.integers(0, P, C)
    return queries.astype(np.uint32), pages


@pytest.mark.parametrize("P,S,Q,C", [
    (16, 128, 32, 1),
    (32, 256, 64, 4),
    (8, 512, 16, 2),
    (64, 128, 128, 3),
])
@pytest.mark.parametrize("kernel", ["perf", "area", "bitserial"])
def test_kernel_vs_oracle(P, S, Q, C, kernel):
    rng = np.random.default_rng(P * 1000 + S + Q + C)
    kp, vp, live = make_pool(rng, P, S)
    pool = layout.interleave(jnp.asarray(kp), jnp.asarray(vp))
    q, pages = make_queries(rng, kp, vp, live, Q, C, P)
    qj, pj = jnp.asarray(q), jnp.asarray(pages)
    want_v, want_f = ref.probe_pages_ref(pool, qj, pj)
    if kernel == "perf":
        got_v, got_f = probe_pages_perf(pool, qj, pj, interpret=True)
    elif kernel == "area":
        got_v, got_f = probe_pages_area(pool, qj, pj, interpret=True)
    else:
        planes = layout.pack_bitplanes(pool[..., 0], 32)
        got_v, got_f = probe_pages_bitserial(planes, pool, qj, pj, 32,
                                             interpret=True)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


@pytest.mark.parametrize("key_bits", [4, 8, 16, 32])
def test_bitserial_key_widths(key_bits):
    """Paper column widths: 4/8/16-bit keys take key_bits bit-plane steps."""
    rng = np.random.default_rng(key_bits)
    P, S, Q, C = 8, 128, 32, 2
    kp, vp, live = make_pool(rng, P, S, key_bits=key_bits, fill=0.4)
    q, pages = make_queries(rng, kp, vp, live, Q, C, P, key_bits=key_bits)
    pool = layout.interleave(jnp.asarray(kp), jnp.asarray(vp))
    qj, pj = jnp.asarray(q), jnp.asarray(pages)
    want_v, want_f = ref.probe_pages_ref(pool, qj, pj)
    planes = layout.pack_bitplanes(pool[..., 0], key_bits)
    got_v, got_f = probe_pages_bitserial(planes, pool, qj, pj, key_bits,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_bitplane_pack_roundtrip():
    rng = np.random.default_rng(0)
    kp = rng.integers(0, 2**32 - 1, (8, 256), dtype=np.uint64).astype(np.uint32)
    planes = layout.pack_bitplanes(jnp.asarray(kp), 32)
    back = layout.unpack_bitplanes(planes, 32)
    np.testing.assert_array_equal(np.asarray(back), kp)


def test_bitplanes_ref_matches_keys_ref():
    rng = np.random.default_rng(1)
    kp, vp, live = make_pool(rng, 16, 128)
    q, pages = make_queries(rng, kp, vp, live, 64, 3, 16)
    pool = layout.interleave(jnp.asarray(kp), jnp.asarray(vp))
    qj, pj = jnp.asarray(q), jnp.asarray(pages)
    planes = layout.pack_bitplanes(pool[..., 0], 32)
    v1, f1 = ref.probe_pages_ref(pool, qj, pj)
    v2, f2 = ref.probe_bitplanes_ref(planes, pool, qj, pj, 32)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_first_match_chain_order():
    """Duplicate key on two pages in the chain: first page wins."""
    kp = np.full((4, 128), 0xFFFFFFFF, np.uint32)
    vp = np.zeros((4, 128), np.uint32)
    kp[1, 5] = 42; vp[1, 5] = 111
    kp[3, 77] = 42; vp[3, 77] = 222
    pool = layout.interleave(jnp.asarray(kp), jnp.asarray(vp))
    q = jnp.asarray([42], jnp.uint32)
    pages = jnp.asarray([[1, 3]], jnp.int32)
    for fn in (ref.probe_pages_ref,
               lambda *a: probe_pages_perf(*a, interpret=True),
               lambda *a: probe_pages_area(*a, interpret=True)):
        v, f = fn(pool, q, pages)
        assert bool(f[0]) and int(v[0]) == 111
    pages2 = jnp.asarray([[3, 1]], jnp.int32)
    v, f = ref.probe_pages_ref(pool, q, pages2)
    assert int(v[0]) == 222
