"""Metrics edge cases (ISSUE 6 bugfix): degenerate sample sets.

``percentile`` used to hand an empty list straight to ``np.percentile``
(IndexError) and ``snapshot()`` could emit NaN/Infinity for a drained
engine (zero completed requests, zero ticks) — and ``Infinity`` is not
even valid JSON, so one idle snapshot corrupted a BENCH trajectory file.
Now every scalar goes through ``finite()`` and ``to_json`` runs with
``allow_nan=False`` as a backstop.
"""
import json
import math

import numpy as np
import pytest

from repro.serving.metrics import MetricsCollector, finite, percentile


def _walk_scalars(obj, path=""):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk_scalars(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            yield from _walk_scalars(v, f"{path}[{i}]")
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, float(obj)


def test_finite_coercion():
    assert finite(1.5) == 1.5
    assert finite(float("nan")) == 0.0
    assert finite(float("inf")) == 0.0
    assert finite(float("-inf")) == 0.0
    assert finite(float("nan"), default=-1.0) == -1.0
    assert finite(np.float64(3.0)) == 3.0


def test_percentile_empty_and_single():
    # empty: no raise, defined value
    assert percentile([], 50) == 0.0
    assert percentile([], 99) == 0.0
    assert percentile(np.array([]), 0) == 0.0
    # single sample: that sample for EVERY q
    for q in (0, 50, 99, 100):
        assert percentile([7.5], q) == 7.5
    # NaN samples are coerced, never propagated
    assert percentile([float("nan")], 50) == 0.0
    # sanity on a real set
    assert percentile(list(range(1, 101)), 50) == pytest.approx(50.5)


def test_empty_snapshot_is_finite_and_json_safe():
    """A collector that never saw a request or a tick must snapshot to
    all-finite scalars and round-trip through strict JSON."""
    m = MetricsCollector()
    snap = m.snapshot()
    scalars = dict(_walk_scalars(snap))
    assert scalars, "snapshot produced no scalars at all?"
    for path, v in scalars.items():
        assert math.isfinite(v), f"non-finite {path} = {v}"
    assert snap["ops_per_sec"] >= 0.0
    assert snap["ops_per_tick"] == 0.0
    assert snap["request_latency_ticks"]["p50"] == 0.0
    assert snap["request_latency_ms"]["p99"] == 0.0
    assert snap["occupancy"]["mean"] == 0.0
    # strict JSON: allow_nan=False raises on any Infinity/NaN leak
    doc = json.loads(m.to_json())
    assert doc["ticks"] == 0 and doc["total_ops"] == 0


def test_single_sample_snapshot():
    m = MetricsCollector()
    m.record_tick(4, 2, 0.001)
    m.record_request(3, 0.002)
    snap = m.snapshot()
    assert snap["request_latency_ticks"]["p50"] == 3
    assert snap["request_latency_ticks"]["p99"] == 3
    assert snap["request_latency_ms"]["p50"] == pytest.approx(2.0)
    assert snap["ops_per_tick"] == 4.0
    json.loads(m.to_json())  # still strict-JSON clean


def test_zero_wall_clock_guard():
    """ops_per_sec with a frozen clock must not emit inf."""
    m = MetricsCollector()
    m.record_tick(10, 1, 0.0)
    m.t0 = __import__("time").perf_counter()  # wall ~ 0
    snap = m.snapshot()
    assert math.isfinite(snap["ops_per_sec"])


# ---------------------------------------------------------------------------
# ISSUE 9: bounded histograms / sketches replace the unbounded lists
# ---------------------------------------------------------------------------

def test_log_histogram_percentiles_within_5pct():
    """Acceptance bar: histogram percentiles within 5% of exact over a
    differential corpus of distributions (exponential, lognormal, uniform,
    zipf-ish heavy tail)."""
    from repro.serving.metrics import LogHistogram
    rng = np.random.default_rng(0)
    corpora = [
        rng.exponential(5e-3, 20_000),           # latency-like
        rng.lognormal(-7.0, 1.5, 20_000),        # heavy-tailed seconds
        rng.uniform(0.0, 100.0, 20_000),
        rng.pareto(1.5, 20_000) + 1.0,
    ]
    for samples in corpora:
        h = LogHistogram(lsb=1e-6)
        for v in samples:
            h.record(v)
        for q in (50, 90, 99, 99.9):
            # nearest-rank exact (the histogram's rank convention; the
            # default linear interpolation differs by a whole inter-sample
            # gap in a heavy tail, which isn't quantization error)
            exact = float(np.percentile(samples, q, method="inverted_cdf"))
            got = h.percentile(q)
            assert got == pytest.approx(exact, rel=0.05), (q, exact, got)
        assert h.mean() == pytest.approx(float(samples.mean()), rel=1e-9)
        assert h.min() == pytest.approx(float(samples.min()))
        assert h.max() == pytest.approx(float(samples.max()))


def test_log_histogram_exact_for_small_integers():
    from repro.serving.metrics import LogHistogram
    h = LogHistogram(lsb=1.0, subbuckets=64)
    # latency-in-ticks style series: all values below 2*64 are EXACT
    for v in [1, 1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 127]:
        h.record(v)
    assert h.percentile(50) == 8       # nearest rank: 6th of 12 samples
    assert h.percentile(100) == 127
    assert h.percentile(0) == 1


def test_log_histogram_memory_is_constant():
    """O(1) in run length: the count array never grows however many
    samples are recorded (the old list-based collector grew per sample)."""
    from repro.serving.metrics import LogHistogram
    h = LogHistogram(lsb=1e-6)
    size0 = h.counts.nbytes
    for i in range(50_000):
        h.record((i % 977) * 1e-5)
    assert h.counts.nbytes == size0
    assert h.count == 50_000


def test_log_histogram_rejects_garbage_gracefully():
    from repro.serving.metrics import LogHistogram
    h = LogHistogram(lsb=1e-6)
    h.record(float("nan"))
    h.record(float("inf"))
    h.record(-5.0)
    h.record(1e30)                       # clamped into the top octave
    assert h.count == 4
    assert math.isfinite(h.percentile(99))


def test_record_ops_rejects_unknown_kind():
    m = MetricsCollector()
    m.record_ops("read", 3, hits=2)
    with pytest.raises(ValueError, match="unknown op kind"):
        m.record_ops("raed", 1)          # the typo that minted phantom keys
    with pytest.raises(ValueError):
        m.record_ops("probe", 1)
    assert set(m.ops) == {"read", "update", "insert", "delete", "scan",
                          "rmw"}


def test_space_saving_sketch_guarantees():
    from repro.serving.metrics import SpaceSaving
    rng = np.random.default_rng(1)
    ss = SpaceSaving(k=16)
    truth: dict = {}
    # zipf-ish stream over ~200 distinct keys
    stream = rng.zipf(1.3, 20_000) % 200
    for k in stream:
        k = int(k)
        truth[k] = truth.get(k, 0) + 1
        ss.offer(k)
    assert len(ss) <= 16
    top_true = sorted(truth, key=lambda k: -truth[k])[:4]
    reported = {k: (c, e) for k, c, e in ss.top(16)}
    for k in top_true:                   # hottest keys are present
        assert k in reported, (k, truth[k])
        c, e = reported[k]
        assert truth[k] <= c <= truth[k] + e   # the classic SS bound


def test_collector_state_is_bounded():
    """snapshot() memory O(1) in run length: drive 10k ticks/requests and
    check no per-sample state accumulated."""
    m = MetricsCollector(chain_sample_every=1)
    from repro.serving.metrics import _CHAIN_WINDOW
    for i in range(10_000):
        m.record_tick(8, 4, 1e-4)
        m.record_request(3, 2e-3, queue_secs=1e-4, service_secs=1.9e-3)
        m.record_phase("gather", 1e-5)
        m.record_hot_keys([i % 500])
        m.chain_samples.append({"tick": i, "chain_p50": 1.0,
                                "chain_p99": 2.0})
    assert len(m.chain_samples) == _CHAIN_WINDOW
    assert len(m.hot) <= 64
    snap = m.snapshot()
    assert len(snap["chain_telemetry"]) == 8
    assert len(snap["hot_keys"]) == 8
    assert snap["requests_completed"] == 10_000
    assert snap["queue_ms"]["p50"] == pytest.approx(0.1, rel=0.02)
    assert snap["service_ms"]["p50"] == pytest.approx(1.9, rel=0.02)
    assert snap["phase_ms"]["gather"]["count"] == 10_000
    json.loads(m.to_json())


def test_to_prom_exposition_format():
    m = MetricsCollector()
    m.record_tick(4, 2, 0.001)
    m.record_request(3, 0.002, queue_secs=5e-4, service_secs=1.5e-3)
    m.record_ops("read", 4, hits=3)
    m.record_phase("gather", 1e-4)
    m.record_hot_keys([0xBEEF] * 3)
    text = m.to_prom()
    assert text.endswith("\n")
    lines = text.splitlines()
    # every non-comment line is "name{labels} value" with a finite value
    for ln in lines:
        if ln.startswith("#"):
            assert ln.startswith("# TYPE hashmem_")
            continue
        name, val = ln.rsplit(" ", 1)
        assert name.startswith("hashmem_")
        assert math.isfinite(float(val)), ln
    assert "hashmem_ticks_total 1" in text
    assert 'hashmem_ops_by_kind_total{kind="read"} 4' in text
    assert 'hashmem_request_latency_seconds{quantile="0.5"}' in text
    assert 'hashmem_phase_seconds{phase="gather",quantile="0.5"}' in text
    assert 'hashmem_hot_key_ops{key="0xbeef"} 3' in text


def test_snapshot_schema_back_compat():
    """The historical snapshot keys the benches/stats consume survive the
    histogram rewrite."""
    m = MetricsCollector()
    m.record_tick(4, 2, 0.001)
    m.record_request(3, 0.002)
    snap = m.snapshot()
    for key in ("wall_seconds", "ticks", "total_ops", "ops_per_sec",
                "ops_per_tick", "requests_completed",
                "request_latency_ticks", "request_latency_ms", "tick_ms",
                "occupancy", "op_counts", "probe_hit_rate",
                "chain_telemetry", "chain_depth", "rows_activated",
                "queue_ms", "service_ms", "phase_ms", "hot_keys"):
        assert key in snap, key
