"""Metrics edge cases (ISSUE 6 bugfix): degenerate sample sets.

``percentile`` used to hand an empty list straight to ``np.percentile``
(IndexError) and ``snapshot()`` could emit NaN/Infinity for a drained
engine (zero completed requests, zero ticks) — and ``Infinity`` is not
even valid JSON, so one idle snapshot corrupted a BENCH trajectory file.
Now every scalar goes through ``finite()`` and ``to_json`` runs with
``allow_nan=False`` as a backstop.
"""
import json
import math

import numpy as np
import pytest

from repro.serving.metrics import MetricsCollector, finite, percentile


def _walk_scalars(obj, path=""):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk_scalars(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            yield from _walk_scalars(v, f"{path}[{i}]")
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, float(obj)


def test_finite_coercion():
    assert finite(1.5) == 1.5
    assert finite(float("nan")) == 0.0
    assert finite(float("inf")) == 0.0
    assert finite(float("-inf")) == 0.0
    assert finite(float("nan"), default=-1.0) == -1.0
    assert finite(np.float64(3.0)) == 3.0


def test_percentile_empty_and_single():
    # empty: no raise, defined value
    assert percentile([], 50) == 0.0
    assert percentile([], 99) == 0.0
    assert percentile(np.array([]), 0) == 0.0
    # single sample: that sample for EVERY q
    for q in (0, 50, 99, 100):
        assert percentile([7.5], q) == 7.5
    # NaN samples are coerced, never propagated
    assert percentile([float("nan")], 50) == 0.0
    # sanity on a real set
    assert percentile(list(range(1, 101)), 50) == pytest.approx(50.5)


def test_empty_snapshot_is_finite_and_json_safe():
    """A collector that never saw a request or a tick must snapshot to
    all-finite scalars and round-trip through strict JSON."""
    m = MetricsCollector()
    snap = m.snapshot()
    scalars = dict(_walk_scalars(snap))
    assert scalars, "snapshot produced no scalars at all?"
    for path, v in scalars.items():
        assert math.isfinite(v), f"non-finite {path} = {v}"
    assert snap["ops_per_sec"] >= 0.0
    assert snap["ops_per_tick"] == 0.0
    assert snap["request_latency_ticks"]["p50"] == 0.0
    assert snap["request_latency_ms"]["p99"] == 0.0
    assert snap["occupancy"]["mean"] == 0.0
    # strict JSON: allow_nan=False raises on any Infinity/NaN leak
    doc = json.loads(m.to_json())
    assert doc["ticks"] == 0 and doc["total_ops"] == 0


def test_single_sample_snapshot():
    m = MetricsCollector()
    m.record_tick(4, 2, 0.001)
    m.record_request(3, 0.002)
    snap = m.snapshot()
    assert snap["request_latency_ticks"]["p50"] == 3
    assert snap["request_latency_ticks"]["p99"] == 3
    assert snap["request_latency_ms"]["p50"] == pytest.approx(2.0)
    assert snap["ops_per_tick"] == 4.0
    json.loads(m.to_json())  # still strict-JSON clean


def test_zero_wall_clock_guard():
    """ops_per_sec with a frozen clock must not emit inf."""
    m = MetricsCollector()
    m.record_tick(10, 1, 0.0)
    m.t0 = __import__("time").perf_counter()  # wall ~ 0
    snap = m.snapshot()
    assert math.isfinite(snap["ops_per_sec"])
