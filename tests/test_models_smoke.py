"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU; asserts output shapes and finiteness (assignment
requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.configs.base import OptimConfig
from repro.models import model
from repro.optim import adamw_update, init_opt_state


def make_batch(cfg, B=2, S=128, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.is_encoder_decoder:
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.float32),
            "dec_tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, 64)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, 64)), jnp.int32),
        }
    if cfg.family == "vlm":
        P_ = cfg.num_prefix_embeds
        return {
            "patch_embeds": jnp.asarray(
                rng.standard_normal((B, P_, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S - P_)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    B = 2

    x, aux = jax.jit(lambda p, b: model.forward(p, cfg, b))(params, batch)
    S_expect = 64 if cfg.is_encoder_decoder else 128
    assert x.shape == (B, S_expect, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))

    oc = OptimConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, oc)

    @jax.jit
    def step(p, o, b):
        (loss, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, cfg, b), has_aux=True)(p)
        p2, o2, stats = adamw_update(p, g, o, oc)
        return p2, o2, loss, stats

    p2, o2, loss, stats = step(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    assert float(stats["grad_norm"]) > 0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_magnitude(arch):
    """Full configs hit the published parameter counts (±15%)."""
    from repro.configs import get_config
    expected = {
        "jamba-v0.1-52b": 52e9, "internvl2-2b": 1.9e9,
        "llama4-maverick-400b-a17b": 400e9, "olmoe-1b-7b": 6.9e9,
        "llama3-8b": 8e9, "qwen3-8b": 8.2e9, "h2o-danube-1.8b": 1.8e9,
        "phi4-mini-3.8b": 3.8e9, "xlstm-1.3b": 1.3e9, "whisper-tiny": 39e6,
    }
    n = model.count_params(get_config(arch))
    assert abs(n - expected[arch]) / expected[arch] < 0.16, n
