"""MoE dispatch/combine correctness + capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import moe
from repro.models.layers import is_leaf


def strip(tree):
    return jax.tree.map(lambda t: t[0], tree, is_leaf=is_leaf)


def dense_moe_reference(p, cfg, x):
    """O(T*E) reference: route every token through its top-k experts."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d).astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros((T, d), jnp.float32)
    for e in range(cfg.num_experts):
        g = jax.nn.silu(xf @ p["gate"][e].astype(jnp.float32))
        u = xf @ p["up"][e].astype(jnp.float32)
        o = (g * u) @ p["down"][e].astype(jnp.float32)
        w = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1)
        y += w[:, None] * o
    return y.reshape(B, S, d)


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "jamba-v0.1-52b",
                                  "llama4-maverick-400b-a17b"])
def test_moe_matches_dense_reference(arch):
    cfg = smoke_config(arch).replace(capacity_factor=16.0, dtype="float32")
    p = strip(moe.init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    y, aux = moe.apply(p, cfg, x)
    ref = dense_moe_reference(p, cfg, x)
    if "shared" in p:
        from repro.models.mlp import swiglu
        ref = ref + swiglu(p["shared"], x.astype(jnp.float32))
    assert float(aux["moe_dropped"]) == 0.0  # capacity 16x -> no drops
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)


def test_capacity_drops_tokens():
    cfg = smoke_config("olmoe-1b-7b").replace(capacity_factor=0.25,
                                              dtype="float32")
    p = strip(moe.init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = moe.apply(p, cfg, x)
    assert float(aux["moe_dropped"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_aux_loss_favors_balance():
    # top-1 routing: max skew factor is E (all mass on one expert)
    cfg = smoke_config("olmoe-1b-7b").replace(dtype="float32", top_k=1)
    p = strip(moe.init(jax.random.PRNGKey(0), cfg))
    # positive activations so a +bias on expert-0's column dominates top-1
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)))
    _, aux = moe.apply(p, cfg, x)
    p_skew = dict(p)
    p_skew["router"] = p["router"].at[:, 0].add(100.0)
    _, aux_skew = moe.apply(p_skew, cfg, x)
    # fully-collapsed routing hits the aux-loss maximum coef*E
    assert float(aux_skew["moe_aux"]) > 0.9 * cfg.aux_loss_coef * cfg.num_experts
    assert float(aux_skew["moe_aux"]) > float(aux["moe_aux"]) * 1.5


def test_hash_routing_mode():
    cfg = smoke_config("olmoe-1b-7b").replace(dtype="float32")
    p = strip(moe.init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe.apply(p, cfg, x, router_mode="hash")
    assert bool(jnp.all(jnp.isfinite(y)))
