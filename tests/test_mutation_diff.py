"""Differential test harness for the online mutation engine.

Randomized mixed insert/probe/delete/grow/compact schedules run against two
real HashMem structures (one plain, one bit-plane-backed) and a pure-Python
dict reference model (tests/model.py).  Every probe is checked across ALL
FOUR backends (ref / area / perf / bitserial); ``stats()`` invariants are
asserted after every grow/compact and at the end of every schedule:

  * live_entries == model population
  * sum(chain_lengths) == free_top (every allocated page is linked)
  * max chain length <= config.max_chain (the insert engine refuses instead
    of silently overflowing the RLU command depth)
  * bit-planes decode back to exactly the key pages
  * tombstones == 0 after grow/compact (rebuilds reclaim the wasted space)

Batch shapes are fixed so the jitted probe kernels compile once per
(backend, arena size); growth follows a deterministic doubling chain, so the
whole suite touches a handful of compiled shapes.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap, layout

from model import DictModel

INSERT_B, DELETE_B, PROBE_B = 8, 4, 16
PLAIN_BACKENDS = ("ref", "perf", "area")
GROW_CAP_BUCKETS = 64          # bounds the set of compiled arena shapes


def _cfg(backend: str) -> HashMemConfig:
    return HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=24,
                         max_chain=4, backend=backend, auto_grow=False)


def _dcfg(backend: str) -> HashMemConfig:
    """Displaced variant: fingerprint lane + H2 displacement + stash (the
    PR-7 probe path).  Same arena shape as _cfg so the sweep reuses the
    compiled probe shapes."""
    return HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=24,
                         max_chain=4, backend=backend, auto_grow=False,
                         displacement=True, fingerprint_bits=8,
                         stash_slots=16)


class DiffHarness:
    """One schedule: two live structures + the dict model, op by op."""

    def __init__(self, seed: int, cfg_fn=_cfg):
        self.rng = np.random.default_rng(seed)
        self.hm_plain = hashmap.create(cfg_fn("perf"))
        self.hm_bits = hashmap.create(cfg_fn("bitserial"))
        self.model = DictModel()
        self.keyspace = self.rng.choice(
            100_000, 256, replace=False).astype(np.uint32)

    # -- ops ---------------------------------------------------------------
    def op_insert(self):
        ks = self.rng.choice(self.keyspace, INSERT_B).astype(np.uint32)
        vs = self.rng.integers(1, 2**31, INSERT_B).astype(np.uint32)
        jk, jv = jnp.asarray(ks), jnp.asarray(vs)
        self.hm_plain, ok1 = hashmap.insert(self.hm_plain, jk, jv)
        self.hm_bits, ok2 = hashmap.insert(self.hm_bits, jk, jv)
        ok1, ok2 = np.asarray(ok1), np.asarray(ok2)
        assert (ok1 == ok2).all(), "backends disagree on PR_ERROR"
        self.model.insert(ks, vs, ok1)

    def op_delete(self):
        live = self.model.keys()
        pool = np.concatenate([np.asarray(live, np.uint32),
                               self.rng.choice(self.keyspace, 4)
                               .astype(np.uint32)]) if live else self.keyspace
        ks = self.rng.choice(pool, DELETE_B).astype(np.uint32)
        jk = jnp.asarray(ks)
        self.hm_plain, f1 = hashmap.delete(self.hm_plain, jk)
        self.hm_bits, f2 = hashmap.delete(self.hm_bits, jk)
        exp = self.model.delete(ks)
        assert (np.asarray(f1) == exp).all()
        assert (np.asarray(f2) == exp).all()

    def op_probe(self):
        live = self.model.keys()
        pool = np.concatenate([np.asarray(live, np.uint32),
                               self.rng.choice(self.keyspace, 8)
                               .astype(np.uint32)]) if live else self.keyspace
        ks = self.rng.choice(pool, PROBE_B).astype(np.uint32)
        expv, expf = self.model.probe(ks)
        expv, expf = np.asarray(expv, np.uint32), np.asarray(expf)
        q = jnp.asarray(ks)
        results = {b: hashmap.probe(self.hm_plain, q, backend=b)
                   for b in PLAIN_BACKENDS}
        results["bitserial"] = hashmap.probe(self.hm_bits, q,
                                             backend="bitserial")
        for b, (v, f) in results.items():
            v, f = np.asarray(v), np.asarray(f)
            assert (f == expf).all(), f"{b}: found mask diverged"
            assert (v[expf] == expv[expf]).all(), f"{b}: values diverged"

    def op_grow(self):
        if self.hm_plain.config.num_buckets >= GROW_CAP_BUCKETS:
            return
        self.hm_plain = hashmap.grow(self.hm_plain)
        self.hm_bits = hashmap.grow(self.hm_bits)
        self.check_invariants(expect_no_tombs=True)

    def op_compact(self):
        self.hm_plain = hashmap.compact(self.hm_plain)
        self.hm_bits = hashmap.compact(self.hm_bits)
        self.check_invariants(expect_no_tombs=True)

    # -- invariants --------------------------------------------------------
    def check_invariants(self, expect_no_tombs: bool = False):
        for hm in (self.hm_plain, self.hm_bits):
            st = hashmap.stats(hm)
            assert st["live_entries"] == self.model.live_entries()
            if expect_no_tombs:
                assert st["tombstones"] == 0
            cl = st["chain_lengths"]
            assert (cl >= 1).all()
            assert st["max_chain"] <= hm.config.max_chain
            assert int(cl.sum()) == int(np.asarray(hm.free_top))
            assert st["free_pages"] == \
                hm.config.num_pages - int(np.asarray(hm.free_top))
            # unified PageStore: the split views are lanes of ONE pool, and
            # slots never written through the fused path keep a zero value
            # lane (EMPTY key => untouched row half)
            pool = np.asarray(hm.store.pool)
            assert pool.shape[-1] == 2 and pool.dtype == np.uint32
            kp = np.asarray(hm.key_pages)
            np.testing.assert_array_equal(pool[..., 0], kp)
            np.testing.assert_array_equal(pool[..., 1],
                                          np.asarray(hm.val_pages))
            assert (pool[..., 1][kp == np.uint32(0xFFFFFFFF)] == 0).all(), \
                "value lane written without its key (fused write violated)"
        decoded = layout.unpack_bitplanes(self.hm_bits.planes,
                                          self.hm_bits.config.key_bits)
        assert bool(jnp.all(decoded == self.hm_bits.key_pages)), \
            "bit-planes out of sync with key pages"


OP_NAMES = np.array(["insert", "probe", "delete", "grow", "compact"])
OP_WEIGHTS = np.array([0.40, 0.25, 0.20, 0.08, 0.07])


def run_schedule(seed: int, n_ops: int, cfg_fn=_cfg):
    h = DiffHarness(seed, cfg_fn)
    ops = h.rng.choice(OP_NAMES, n_ops, p=OP_WEIGHTS)
    for op in ops:
        getattr(h, f"op_{op}")()
    h.op_probe()
    h.check_invariants(expect_no_tombs=False)
    return h


# ---------------------------------------------------------------------------
# The differential sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(40))
def test_diff_schedule(seed):
    """Tier-1 slice of the randomized sweep (fast; ~12 mixed ops each)."""
    run_schedule(seed, n_ops=12)


@pytest.mark.slow
@pytest.mark.parametrize("block", range(10))
def test_diff_schedule_sweep_500(block):
    """The full 500-schedule acceptance sweep, 50 schedules per block."""
    for seed in range(1000 + block * 50, 1000 + (block + 1) * 50):
        run_schedule(seed, n_ops=12)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [7, 8])
def test_diff_schedule_long(seed):
    """>1k-op schedules (slow marker per tests/conftest.py)."""
    run_schedule(seed, n_ops=1200)


@pytest.mark.parametrize("seed", range(20))
def test_diff_schedule_displaced(seed):
    """The randomized sweep on the fingerprint+displacement+stash config:
    same model, same four-backend probe checks, with H2 relocation and the
    stash live through grow/compact rebuilds."""
    run_schedule(seed, n_ops=12, cfg_fn=_dcfg)


# ---------------------------------------------------------------------------
# Directed mutation-engine tests
# ---------------------------------------------------------------------------

def test_insert_matches_scan_reference():
    """The vectorized insert must be element-for-element equivalent to the
    sequential lax.scan reference on collision-heavy batches."""
    cfg = _cfg("bitserial")
    rng = np.random.default_rng(3)
    hm_v = hashmap.create(cfg)
    hm_s = hashmap.create(cfg)
    for _ in range(6):
        ks = rng.integers(0, 64, 32).astype(np.uint32)   # heavy duplication
        vs = rng.integers(1, 2**31, 32).astype(np.uint32)
        hm_v, ok_v = hashmap.insert(hm_v, jnp.asarray(ks), jnp.asarray(vs))
        hm_s, ok_s = hashmap.insert_scan(hm_s, jnp.asarray(ks), jnp.asarray(vs))
        assert (np.asarray(ok_v) == np.asarray(ok_s)).all()
        for field in ("key_pages", "val_pages", "page_next", "page_fill",
                      "free_top", "planes"):
            a, b = getattr(hm_v, field), getattr(hm_s, field)
            assert bool(jnp.all(a == b)), f"{field} diverged from scan reference"


def test_duplicate_keys_fifo_order_across_grow():
    """Duplicates: probe returns the oldest, delete pops the oldest, and the
    order survives grow and compact rebuilds."""
    cfg = _cfg("perf")
    hm = hashmap.create(cfg)
    k = jnp.asarray([42, 42, 42], jnp.uint32)
    v = jnp.asarray([1, 2, 3], jnp.uint32)
    hm, ok = hashmap.insert(hm, k, v)
    assert bool(jnp.all(ok))
    hm = hashmap.grow(hm)
    hm = hashmap.compact(hm)
    for expect in (1, 2, 3):
        val, f = hashmap.probe(hm, jnp.asarray([42], jnp.uint32))
        assert bool(f[0]) and int(val[0]) == expect
        hm, fd = hashmap.delete(hm, jnp.asarray([42], jnp.uint32))
        assert bool(fd[0])
    _, f = hashmap.probe(hm, jnp.asarray([42], jnp.uint32))
    assert not bool(f[0])


def test_tombstone_then_reinsert_then_compact():
    cfg = _cfg("bitserial")
    hm = hashmap.create(cfg)
    keys = np.arange(100, 140, dtype=np.uint32)
    hm, _ = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(keys * 2))
    hm, _ = hashmap.delete(hm, jnp.asarray(keys))
    assert hashmap.stats(hm)["tombstones"] == 40
    # re-insert same keys with new values: appended past the tombstones
    hm, ok = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(keys * 5))
    assert bool(jnp.all(ok))
    assert hashmap.stats(hm)["tombstones"] == 40     # not reused (paper §2.5)
    hm = hashmap.compact(hm)
    st = hashmap.stats(hm)
    assert st["tombstones"] == 0 and st["live_entries"] == 40
    v, f = hashmap.probe(hm, jnp.asarray(keys))
    assert bool(jnp.all(f)) and bool(jnp.all(v == jnp.asarray(keys * 5)))


def test_arena_exhaustion_triggers_grow():
    """insert_auto: the PR_ERROR path becomes a resize, no dropped writes."""
    cfg = HashMemConfig(num_buckets=2, slots_per_page=32, overflow_pages=2,
                        max_chain=3, backend="ref")  # capacity 128 slots
    hm = hashmap.create(cfg)
    keys = np.random.default_rng(5).choice(
        2**31, 600, replace=False).astype(np.uint32)
    # plain insert drops writes...
    _, ok_plain = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(keys))
    assert not bool(jnp.all(ok_plain))
    # ...insert_auto grows instead
    hm, ok = hashmap.insert_auto(hm, jnp.asarray(keys), jnp.asarray(keys))
    assert bool(jnp.all(ok))
    assert hm.config.num_buckets > cfg.num_buckets
    v, f = hashmap.probe(hm, jnp.asarray(keys))
    assert bool(jnp.all(f)) and bool(jnp.all(v == jnp.asarray(keys)))
    st = hashmap.stats(hm)
    assert st["live_entries"] == 600
    assert st["max_chain"] <= hm.config.max_chain


def test_max_load_factor_proactive_grow():
    cfg = HashMemConfig(num_buckets=4, slots_per_page=32, overflow_pages=4,
                        max_chain=4, backend="ref", max_load_factor=0.5)
    hm = hashmap.create(cfg)                          # capacity 256
    keys = np.arange(1, 200, dtype=np.uint32)         # 199 > 0.5 * 256
    hm, ok = hashmap.insert_auto(hm, jnp.asarray(keys), jnp.asarray(keys))
    assert bool(jnp.all(ok))
    assert hm.config.num_buckets > 4                  # grew before exhaustion
    assert hashmap.stats(hm)["load_factor"] <= 0.5


def test_sharded_insert_with_synchronized_growth():
    """RLU channel layer: routed insert, exhaustion grows ALL shards so the
    stacked pytree stays homogeneous, probes agree afterwards."""
    from repro.core import rlu
    num_shards = 2
    cfg = HashMemConfig(num_buckets=4, slots_per_page=32, overflow_pages=4,
                        max_chain=3, backend="ref")
    rng = np.random.default_rng(9)
    k0 = rng.choice(2**31, 64, replace=False).astype(np.uint32)
    hm_stacked = rlu.build_sharded(cfg, jnp.asarray(k0), jnp.asarray(k0 * 2),
                                   num_shards)
    # way past per-shard capacity (2 shards x 256 slots, minus EMPTY padding)
    k1 = np.setdiff1d(rng.choice(2**31, 900, replace=False).astype(np.uint32),
                      k0)
    hm_stacked, ok, cfg2 = rlu.insert_sharded(
        hm_stacked, jnp.asarray(k1), jnp.asarray(k1 * 2), cfg, num_shards)
    assert bool(jnp.all(ok))
    assert cfg2.num_buckets > cfg.num_buckets
    # per-shard configs stayed homogeneous; probe every key on its owner
    import jax
    owner, _ = rlu.owner_and_local_bucket(jnp.asarray(np.concatenate([k0, k1])),
                                          cfg2, num_shards)
    owner = np.asarray(owner)
    allk = np.concatenate([k0, k1])
    for d in range(num_shards):
        hm_d = jax.tree.map(lambda x, d=d: x[d], hm_stacked)
        assert hm_d.config.num_buckets == cfg2.num_buckets
        mine = allk[owner == d]
        v, f = rlu._local_probe(hm_d, jnp.asarray(mine), cfg2, num_shards)
        assert bool(jnp.all(f))
        assert bool(jnp.all(v == jnp.asarray(mine * 2)))


def test_churn_workload_diff():
    """Replay a data-layer churn stream (Zipf-skewed mixed ops) through
    insert_auto + the dict model: the serving-shaped workload, end to end."""
    from repro.data.kv_synth import churn_workload
    cfg = HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=8,
                        max_chain=4, backend="ref")
    hm = hashmap.create(cfg)
    m = DictModel()
    for op, ks, vs in churn_workload(80, keyspace=128, seed=21):
        jk = jnp.asarray(ks)
        if op == "insert":
            hm, ok = hashmap.insert_auto(hm, jk, jnp.asarray(vs))
            assert bool(jnp.all(ok))                 # auto-grow: no drops
            m.insert(ks, vs, np.asarray(ok))
        elif op == "delete":
            hm, f = hashmap.delete(hm, jk)
            assert (np.asarray(f) == m.delete(ks)).all()
        else:
            expv, expf = m.probe(ks)
            v, f = hashmap.probe(hm, jk)
            v, f = np.asarray(v), np.asarray(f)
            expv, expf = np.asarray(expv, np.uint32), np.asarray(expf)
            assert (f == expf).all()
            assert (v[expf] == expv[expf]).all()
    st = hashmap.stats(hm)
    assert st["live_entries"] == m.live_entries()
    assert st["max_chain"] <= hm.config.max_chain


def test_grow_preserves_probe_on_all_backends():
    for backend in ("ref", "perf", "area", "bitserial"):
        cfg = _cfg(backend)
        rng = np.random.default_rng(13)
        keys = rng.choice(2**31, 400, replace=False).astype(np.uint32)
        hm = hashmap.create(cfg)
        hm, ok = hashmap.insert_auto(hm, jnp.asarray(keys),
                                     jnp.asarray(keys + 7))
        assert bool(jnp.all(ok))
        v, f = hashmap.probe(hm, jnp.asarray(keys))
        assert bool(jnp.all(f)), backend
        assert bool(jnp.all(v == jnp.asarray(keys + 7))), backend


def _probe_all_backends(hm, q, expv, expf):
    """Bit-check a probe across all four backends on one (bitserial-built,
    so planes exist) structure."""
    for b in ("ref", "perf", "area", "bitserial"):
        v, f = hashmap.probe(hm, jnp.asarray(q), backend=b)
        v, f = np.asarray(v), np.asarray(f)
        assert (f == expf).all(), f"{b}: found mask diverged"
        assert (v[expf] == expv[expf]).all(), f"{b}: values diverged"


def test_one_bucket_displacement_into_stash():
    """Adversarial all-keys-one-bucket schedule: every key's H1 AND H2 hash
    to the same bucket (mined in tests/model.py), so H2 relocation is
    useless — inserts fill the direct page, the one allowed overflow page
    (max_chain=2), and spill into the stash.  insert -> probe -> delete ->
    grow with stash entries live, bit-checked across all four backends."""
    from model import mine_bucket_colliding_keys
    cfg = HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=8,
                        max_chain=2, backend="bitserial", auto_grow=False,
                        displacement=True, fingerprint_bits=8,
                        stash_slots=16)
    keys = mine_bucket_colliding_keys(72, cfg.num_buckets, same_b2=True)
    vals = keys * np.uint32(2) + np.uint32(1)
    hm = hashmap.create(cfg)
    hm, ok = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(vals))
    assert bool(jnp.all(ok))
    st = hashmap.stats(hm)
    # 32 direct + 32 chained + 8 stash, in insert order (FIFO classes)
    assert st["stash_live"] == 8
    assert st["live_entries"] == 72
    assert st["max_chain"] <= cfg.max_chain
    _probe_all_backends(hm, keys, vals, np.ones(72, bool))

    # delete across all three classes: direct, chained, and stash keys
    dk = np.concatenate([keys[30:34], keys[64:68]])
    hm, f = hashmap.delete(hm, jnp.asarray(dk))
    assert bool(jnp.all(f))
    st = hashmap.stats(hm)
    assert st["stash_live"] == 4 and st["stash_tombstones"] == 4
    assert st["live_entries"] == 64
    alive = np.ones(72, bool)
    alive[30:34] = alive[64:68] = False
    _probe_all_backends(hm, keys, vals, alive)

    # grow with stash entries live: the rebuild must replay them (oldest
    # class order) and reclaim every tombstone
    hm = hashmap.grow(hm)
    st = hashmap.stats(hm)
    assert st["live_entries"] == 64 and st["tombstones"] == 0
    assert hm.config.num_buckets == 2 * cfg.num_buckets
    _probe_all_backends(hm, keys, vals, alive)
    decoded = layout.unpack_bitplanes(hm.planes, hm.config.key_bits)
    assert bool(jnp.all(decoded == hm.key_pages)), \
        "bit-planes out of sync after displaced rebuild"


def test_displacement_relocates_instead_of_chaining():
    """Same H1 bucket but every key's H2 differs from H1: the overflow past
    the direct page must relocate to the H2 direct pages — NO overflow page
    allocation, NO stash occupancy (the Dash/IcebergHT win the rows-
    activated bench measures)."""
    from model import mine_bucket_colliding_keys
    cfg = HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=8,
                        max_chain=2, backend="bitserial", auto_grow=False,
                        displacement=True, fingerprint_bits=8,
                        stash_slots=16)
    keys = mine_bucket_colliding_keys(40, cfg.num_buckets, same_b2=False)
    vals = keys + np.uint32(5)
    hm = hashmap.create(cfg)
    hm, ok = hashmap.insert(hm, jnp.asarray(keys), jnp.asarray(vals))
    assert bool(jnp.all(ok))
    st = hashmap.stats(hm)
    assert st["stash_live"] == 0
    # free_top untouched: all 40 landed in direct pages (H1 or H2)
    assert int(np.asarray(hm.free_top)) == cfg.num_buckets
    assert st["live_entries"] == 40
    _probe_all_backends(hm, keys, vals, np.ones(40, bool))


def test_displaced_schedules_through_mesh_engine():
    """The serving differential sweep on the displaced+fingerprint config,
    through BOTH shard backends (host shards as the reference, mesh fused
    and unfused against it) on 2 forced devices — stash state included in
    the per-shard ownership/population checks."""
    from test_serving_sharded import run_sub
    run_sub("""
        from sharded_driver import sweep
        sweep(seed0=9100, n=8, depths=(2,), zipfian="mixed",
              per_request_every=4, displaced=True)
        """)


def test_zipfian_schedules_through_mesh_engine():
    """The randomized mixed-schedule differential harness, routed through
    the MESH-BACKED ServingEngine on 2 forced devices (subprocess pattern
    from test_distributed.py; driver shared with test_serving_sharded.py):
    zipfian-contended and uniform schedules, pipelining off and on, every
    run replayed against the DictModel and bit-compared to the host-shard
    reference — coalesced == per-request == sequential on every shard."""
    from test_serving_sharded import run_sub
    run_sub("""
        from sharded_driver import sweep
        # all-zipfian block, per-request baseline every 4th schedule
        sweep(seed0=7000, n=24, depths=(2,), zipfian="all",
              per_request_every=4)
        """)


def test_zipfian_workload_diff():
    """The serving loadgen's Zipfian skew schedule (shared generator in
    data/kv_synth.py) replayed through the differential harness path:
    hot-key duplicate pileups + tombstone churn against the dict model."""
    from repro.data.kv_synth import zipfian_workload
    cfg = HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=8,
                        max_chain=4, backend="ref")
    hm = hashmap.create(cfg)
    m = DictModel()
    for op, ks, vs in zipfian_workload(80, keyspace=96, theta=0.99,
                                       workload="A", seed=11):
        jk = jnp.asarray(ks)
        if op == "insert":
            hm, ok = hashmap.insert_auto(hm, jk, jnp.asarray(vs))
            assert bool(jnp.all(ok))
            m.insert(ks, vs, np.asarray(ok))
        elif op == "delete":
            hm, f = hashmap.delete(hm, jk)
            assert (np.asarray(f) == m.delete(ks)).all()
        else:
            expv, expf = m.probe(ks)
            v, f = hashmap.probe(hm, jk)
            v, f = np.asarray(v), np.asarray(f)
            expv, expf = np.asarray(expv, np.uint32), np.asarray(expf)
            assert (f == expf).all()
            assert (v[expf] == expv[expf]).all()
    st = hashmap.stats(hm)
    assert st["live_entries"] == m.live_entries()
    assert st["max_chain"] <= hm.config.max_chain
