import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimConfig
from repro.optim import adamw_update, init_opt_state, lr_schedule


def test_schedule_shape():
    oc = OptimConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(oc, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[-1] < lrs[1]                   # decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9       # floor 10%


def test_adamw_converges_quadratic():
    oc = OptimConfig(lr=0.05, warmup_steps=5, total_steps=200,
                     weight_decay=0.0, grad_clip=1.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, oc)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, oc)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_bf16_states_still_converge():
    oc = OptimConfig(lr=0.05, warmup_steps=5, total_steps=200,
                     weight_decay=0.0, state_dtype="bfloat16")
    target = jnp.asarray([0.5, -1.5])
    params = {"w": jnp.zeros(2)}
    state = init_opt_state(params, oc)
    assert state["m"]["w"].dtype == jnp.bfloat16
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, oc)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.1)


def test_grad_clip_caps_update():
    oc = OptimConfig(lr=1.0, warmup_steps=0, total_steps=10, grad_clip=1e-3,
                     weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params, oc)
    g = {"w": jnp.full(4, 1e6)}
    _, _, stats = adamw_update(params, g, state, oc)
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_no_decay_on_norm_scales():
    oc = OptimConfig(lr=0.1, warmup_steps=0, total_steps=10,
                     weight_decay=1.0)
    params = {"ffn": {"w": jnp.ones(4)}, "norm1": {"scale": jnp.ones(4)}}
    state = init_opt_state(params, oc)
    g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(params, g, state, oc)
    assert float(jnp.max(jnp.abs(p2["norm1"]["scale"] - 1.0))) < 1e-6
    assert float(jnp.max(jnp.abs(p2["ffn"]["w"] - 1.0))) > 1e-3  # decayed
