"""Paged KV cache: manager invariants + decode-vs-forward equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServeConfig, smoke_config
from repro.configs.base import ShapeConfig
from repro.core.paged_kv import PageTableManager
from repro.models import model


def test_manager_alloc_free_invariants():
    mgr = PageTableManager(64, num_channels=4, backend="ref")
    bt1 = mgr.alloc_seq(1, 8)
    bt2 = mgr.alloc_seq(2, 8)
    # grouped-layout guarantee: logical page j lives in arena j % Dm,
    # i.e. physical id // pages_per_shard == j % Dm (group 0)
    for j, p in enumerate(bt1):
        assert p // mgr.pps == j % 4
    assert mgr.live_pages() == 16
    # resolve via HashMem probe equals allocation order
    table = mgr.block_table([1, 2], 8)
    np.testing.assert_array_equal(table[0], bt1)
    np.testing.assert_array_equal(table[1], bt2)
    # free -> tombstoned in table, pages recycled
    mgr.free_seq(1)
    assert mgr.live_pages() == 8
    from repro.core import hashmap
    assert hashmap.stats(mgr.hm)["tombstones"] == 8
    bt3 = mgr.alloc_seq(3, 8)
    assert set(bt3) == set(bt1)  # recycled the exact pages
    table = mgr.block_table([3], 8)
    np.testing.assert_array_equal(table[0], bt3)


def test_chain_len_triggered_compaction():
    """Skewed alloc/free churn piles tombstoned pages onto hot page-table
    chains long before the global tombstone fraction trips; the
    ``compact_chain_len`` trigger reclaims them, the fraction-only control
    manager does not."""
    from repro.configs.base import HashMemConfig
    from repro.core import hashmap
    from repro.data.kv_synth import churn_workload

    def run(compact_chain_len):
        # few buckets -> hot chains; fraction trigger effectively disabled
        cfg = HashMemConfig(num_buckets=4, slots_per_page=32,
                            overflow_pages=64, max_chain=8, backend="ref",
                            auto_grow=False, compact_tombstone_frac=1.0,
                            compact_chain_len=compact_chain_len)
        mgr = PageTableManager(64, num_channels=1, hashmem_cfg=cfg)
        peak = 0
        # Zipf-skewed op stream: hot seq ids are allocated and freed over
        # and over -> tombstone churn concentrated on a few buckets
        for op, ks, _ in churn_workload(240, keyspace=64, seed=23,
                                        p_insert=0.5, p_delete=0.4):
            seqs = sorted({int(k) % 24 for k in ks})
            if op == "insert":
                for s in seqs:
                    if s not in mgr.owned and mgr.live_pages() + 2 <= 64:
                        mgr.alloc_seq(s, 2)
            elif op == "delete":
                for s in seqs:
                    mgr.free_seq(s)
            peak = max(peak, hashmap.max_chain_len(mgr.hm))
        # table still resolves every live sequence after compactions
        live = sorted(mgr.owned)
        if live:
            table = mgr.block_table(live, 2)
            for i, s in enumerate(live):
                np.testing.assert_array_equal(table[i], mgr.owned[s])
        return mgr, peak

    mgr_chain, peak_chain = run(compact_chain_len=2)
    mgr_ctrl, peak_ctrl = run(compact_chain_len=0)
    assert mgr_chain.compact_events >= 1
    assert mgr_ctrl.compact_events == 0          # fraction never trips
    assert peak_chain < peak_ctrl                # chains actually kept short
    assert hashmap.max_chain_len(mgr_chain.hm) <= \
        hashmap.max_chain_len(mgr_ctrl.hm)


def test_manager_exhaustion():
    mgr = PageTableManager(8, num_channels=2, backend="ref")
    mgr.alloc_seq(1, 8)
    with pytest.raises(MemoryError):
        mgr.alloc_seq(2, 2)


@pytest.mark.parametrize("backend", ["ref", "perf"])
def test_manager_probe_backends(backend):
    mgr = PageTableManager(32, num_channels=1, backend=backend)
    for s in range(3):
        mgr.alloc_seq(s, 4)
    t = mgr.block_table([0, 1, 2], 4)
    assert t.shape == (3, 4)
    assert len(np.unique(t)) == 12


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-8b", "phi4-mini-3.8b",
                                  "h2o-danube-1.8b", "internvl2-2b",
                                  "olmoe-1b-7b"])
def test_decode_matches_forward(arch):
    """Greedy paged decode reproduces teacher-forced forward logits."""
    cfg = smoke_config(arch).replace(remat=False, dtype="float32",
                                     capacity_factor=8.0)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    scfg = ServeConfig(model=cfg, shape=ShapeConfig("t", S, B, "decode"),
                       kv_page_tokens=8)
    ctx = model.make_decode_ctx(cfg, scfg, B)
    states = model.init_decode_states(params, cfg, B, ctx,
                                      kv_dtype=jnp.float32)
    if cfg.family == "vlm":
        batch = {"patch_embeds": jnp.zeros((B, cfg.num_prefix_embeds,
                                            cfg.d_model), jnp.float32),
                 "tokens": tokens[:, :S - cfg.num_prefix_embeds],
                 "labels": tokens}
        pytest.skip("vlm decode covered via dense trunk equivalence elsewhere")
    batch = {"tokens": tokens, "labels": tokens}
    x, _ = model.forward(params, cfg, batch)
    full = model.logits_fn(params, cfg, x)
    bt = jnp.asarray(np.arange(B * ctx.n_pages, dtype=np.int32)
                     .reshape(B, ctx.n_pages))
    step = jax.jit(lambda p, s, t, pos, bt_: model.decode_step(
        p, cfg, s, t, pos, bt_, ctx))
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, states = step(params, states, tokens[:, t:t + 1], pos, bt)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]),
            rtol=5e-4, atol=5e-4)


def test_sliding_window_decode_page_bound():
    """SWA archs bound the paged horizon to the window (DESIGN §3)."""
    cfg = smoke_config("h2o-danube-1.8b")
    scfg = ServeConfig(model=cfg,
                       shape=ShapeConfig("t", 8192, 2, "decode"),
                       kv_page_tokens=32)
    ctx = model.make_decode_ctx(cfg, scfg, 2)
    assert ctx.n_pages <= (cfg.sliding_window + 32) // 32 + 1


def test_alloc_seqs_free_seqs_coalesced_equivalence():
    """Batched alloc/free (one HashMem call per step) resolves to exactly
    the same page tables as the per-sequence calls, and issues ONE batched
    insert for the whole admission wave (counted via hashmap call hooks)."""
    from repro.core import hashmap

    mgr_a = PageTableManager(64, num_channels=2, backend="ref")
    for s in range(3):
        mgr_a.alloc_seq(s, 4)
    mgr_b = PageTableManager(64, num_channels=2, backend="ref")
    calls = {"n": 0}
    orig_auto, orig_ins = hashmap.insert_auto, hashmap.insert

    def count_auto(*a, **k):
        calls["n"] += 1
        return orig_auto(*a, **k)

    def count_ins(*a, **k):
        calls["n"] += 1
        return orig_ins(*a, **k)

    hashmap.insert_auto, hashmap.insert = count_auto, count_ins
    try:
        phys = mgr_b.alloc_seqs([(s, 4, 0) for s in range(3)])
    finally:
        hashmap.insert_auto, hashmap.insert = orig_auto, orig_ins
    assert calls["n"] == 1                       # one call for 3 sequences
    np.testing.assert_array_equal(mgr_a.block_table([0, 1, 2], 4),
                                  mgr_b.block_table([0, 1, 2], 4))
    for s in range(3):
        np.testing.assert_array_equal(phys[s], mgr_b.owned[s])

    mgr_b.free_seqs([0, 2])
    assert sorted(mgr_b.owned) == [1]
    t = mgr_b.block_table([1], 4)
    np.testing.assert_array_equal(t[0], mgr_b.owned[1])
    assert mgr_b.alloc_seqs([]) == {}            # empty wave is a no-op


def test_manager_tick_compacts_without_frees():
    """The engine-tick hook reclaims tombstones even when no free ever
    happens again (maybe_compact used to run only inside free_seq)."""
    from repro.configs.base import HashMemConfig

    cfg = HashMemConfig(num_buckets=4, slots_per_page=4, overflow_pages=64,
                        max_chain=8, backend="ref", auto_grow=False,
                        compact_tombstone_frac=1.0, compact_chain_len=2)
    mgr = PageTableManager(64, num_channels=1, hashmem_cfg=cfg)
    # skewed alloc/free churn with the chain walk throttled so the frees
    # themselves never observe the over-long chains
    for r in range(3):
        for s in range(6):
            mgr.alloc_seq(100 * r + s, 2)
        mgr._frees_since_chain_check = -10_000   # throttle holds during frees
        mgr.free_seqs([100 * r + s for s in range(6)])
    assert mgr.compact_events == 0
    assert mgr._tombstones > 0
    mgr._frees_since_chain_check = mgr.CHAIN_CHECK_EVERY
    before = mgr.compact_events
    for _ in range(mgr.CHAIN_CHECK_EVERY + 1):
        mgr.tick()                               # no frees, tick clock only
    assert mgr.compact_events > before
    assert mgr._tombstones == 0


def test_alloc_seq_zero_blocks():
    """alloc_seq(s, 0) returns an empty table (pre-batching behavior), and
    free_seq of it is a no-op."""
    mgr = PageTableManager(32, num_channels=1, backend="ref")
    bt = mgr.alloc_seq(7, 0)
    assert bt.shape == (0,)
    assert mgr.live_pages() == 0
    mgr.free_seq(7)
    assert mgr.compact_events == 0
