"""Unified PageStore round-trips: the fused key/value write path vs
independent per-lane scatters, the thin split views, and bit-plane
consistency after store-routed writes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap, layout
from repro.core.hashing import EMPTY_KEY, TOMBSTONE_KEY


def _fresh(P=8, S=64, key_bits=32, with_planes=True):
    return layout.empty_store(P, S, key_bits, with_planes=with_planes)


def _writes(rng, P, S, B, oob=0):
    """B unique (page, slot) targets (+``oob`` out-of-range pages at the end)."""
    flat = rng.choice(P * S, size=B, replace=False)
    pages = (flat // S).astype(np.int32)
    slots = (flat % S).astype(np.int32)
    if oob:
        pages = np.concatenate([pages, np.full(oob, P, np.int32)])
        slots = np.concatenate([slots, np.zeros(oob, np.int32)])
    keys = rng.integers(0, 2**31, pages.size).astype(np.uint32)
    vals = rng.integers(0, 2**31, pages.size).astype(np.uint32)
    return map(jnp.asarray, (pages, slots, keys, vals))


@pytest.mark.parametrize("with_planes", [False, True])
def test_write_slots_matches_independent_scatters(with_planes):
    """ONE fused pool scatter == the split layout's two independent key/val
    scatters, exactly (including mode="drop" on out-of-range pages)."""
    rng = np.random.default_rng(0)
    store = _fresh(with_planes=with_planes)
    pages, slots, keys, vals = _writes(rng, 8, 64, 48, oob=4)
    out = store.write_slots(pages, slots, keys, vals)
    # independent split-pool reference
    want_k = store.key_pages.at[pages, slots].set(keys, mode="drop")
    want_v = store.val_pages.at[pages, slots].set(vals, mode="drop")
    np.testing.assert_array_equal(np.asarray(out.key_pages),
                                  np.asarray(want_k))
    np.testing.assert_array_equal(np.asarray(out.val_pages),
                                  np.asarray(want_v))
    if with_planes:
        decoded = layout.unpack_bitplanes(out.planes, out.key_bits)
        np.testing.assert_array_equal(np.asarray(decoded),
                                      np.asarray(out.key_pages))
    else:
        assert out.planes is None


def test_interleaved_views():
    """key_pages/val_pages are lane views of the one pool; shapes and dtypes
    match the split layout contract."""
    rng = np.random.default_rng(1)
    store = _fresh(P=4, S=32, with_planes=False)
    assert store.pool.shape == (4, 32, 2) and store.pool.dtype == jnp.uint32
    assert store.num_pages == 4 and store.slots == 32
    assert bool(jnp.all(store.key_pages == EMPTY_KEY))
    assert bool(jnp.all(store.val_pages == 0))
    pages, slots, keys, vals = _writes(rng, 4, 32, 16)
    out = store.write_slots(pages, slots, keys, vals)
    np.testing.assert_array_equal(np.asarray(out.pool[..., layout.KEY_LANE]),
                                  np.asarray(out.key_pages))
    np.testing.assert_array_equal(np.asarray(out.pool[..., layout.VAL_LANE]),
                                  np.asarray(out.val_pages))
    # round-trip through interleave()
    re = layout.interleave(out.key_pages, out.val_pages)
    np.testing.assert_array_equal(np.asarray(re), np.asarray(out.pool))


def test_write_keys_tombstone_leaves_values():
    """Tombstone writes rewrite the key lane only — the value is the paper's
    'wasted space' until compact()."""
    rng = np.random.default_rng(2)
    store = _fresh(with_planes=True)
    pages, slots, keys, vals = _writes(rng, 8, 64, 32)
    store = store.write_slots(pages, slots, keys, vals)
    t = jnp.full((8,), TOMBSTONE_KEY, jnp.uint32)
    out = store.write_keys(pages[:8], slots[:8], t)
    kp = np.asarray(out.key_pages)
    assert (kp[np.asarray(pages[:8]), np.asarray(slots[:8])]
            == np.uint32(0xFFFFFFFE)).all()
    np.testing.assert_array_equal(np.asarray(out.val_pages),
                                  np.asarray(store.val_pages))
    decoded = layout.unpack_bitplanes(out.planes, out.key_bits)
    np.testing.assert_array_equal(np.asarray(decoded), kp)


def test_store_routed_mutations_keep_planes_consistent():
    """Bit-planes stay exactly in sync with the key lane through a
    store-routed insert/delete/insert sequence on a live HashMem."""
    cfg = HashMemConfig(num_buckets=8, slots_per_page=32, overflow_pages=16,
                        max_chain=4, backend="bitserial", auto_grow=False)
    rng = np.random.default_rng(3)
    hm = hashmap.create(cfg)
    for step in range(4):
        ks = rng.choice(500, 16).astype(np.uint32)
        hm, _ = hashmap.insert(hm, jnp.asarray(ks), jnp.asarray(ks * 7))
        hm, _ = hashmap.delete(hm, jnp.asarray(ks[:4]))
        decoded = layout.unpack_bitplanes(hm.planes, cfg.key_bits)
        assert bool(jnp.all(decoded == hm.key_pages)), step


def test_hashmem_views_alias_store():
    """HashMem's split-view properties are exactly the store's lanes and
    bookkeeping (the migration shim for external callers)."""
    cfg = HashMemConfig(num_buckets=4, slots_per_page=32, overflow_pages=8,
                        max_chain=3, backend="perf", auto_grow=False)
    hm = hashmap.create(cfg)
    ks = jnp.arange(1, 40, dtype=jnp.uint32)
    hm, _ = hashmap.insert(hm, ks, ks * 2)
    np.testing.assert_array_equal(np.asarray(hm.key_pages),
                                  np.asarray(hm.store.pool[..., 0]))
    np.testing.assert_array_equal(np.asarray(hm.val_pages),
                                  np.asarray(hm.store.pool[..., 1]))
    assert hm.page_next is hm.store.page_next
    assert hm.page_fill is hm.store.page_fill
    assert hm.free_top is hm.store.free_top
    # never-written slots keep a zero value lane (the fused write is the only
    # path that touches the value lane)
    kp, vp = np.asarray(hm.key_pages), np.asarray(hm.val_pages)
    assert (vp[kp == np.uint32(0xFFFFFFFF)] == 0).all()


def test_store_is_a_pytree():
    """PageStore leaves stack/map like any pytree (the RLU shard layout)."""
    import jax
    s1 = _fresh(P=4, S=32, with_planes=True)
    s2 = _fresh(P=4, S=32, with_planes=True)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), s1, s2)
    assert stacked.pool.shape == (2, 4, 32, 2)
    back = jax.tree.map(lambda x: x[1], stacked)
    np.testing.assert_array_equal(np.asarray(back.pool), np.asarray(s2.pool))
    assert back.key_bits == 32
