import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pim_embedding import DictionaryVocab, init_qr, qr_embedding


def test_dictionary_vocab_roundtrip():
    rng = np.random.default_rng(0)
    keys = rng.choice(2**31, 5000, replace=False).astype(np.uint32)
    vocab = DictionaryVocab(keys)
    rows, found = vocab.encode(jnp.asarray(keys[:512]))
    assert bool(jnp.all(found))
    assert bool(jnp.all(rows == jnp.arange(512)))


def test_oov_maps_to_last_row():
    rng = np.random.default_rng(1)
    keys = rng.choice(2**30, 1000, replace=False).astype(np.uint32)
    vocab = DictionaryVocab(keys)
    unknown = (keys[:64].astype(np.uint64) + 2**30).astype(np.uint32)
    rows, found = vocab.encode(jnp.asarray(unknown))
    assert not bool(jnp.any(found))
    assert bool(jnp.all(rows == vocab.size))
    table = jnp.asarray(np.arange((vocab.size + 1) * 4, dtype=np.float32)
                        .reshape(vocab.size + 1, 4))
    emb = vocab.lookup(table, jnp.asarray(unknown))
    np.testing.assert_array_equal(np.asarray(emb[0]), np.asarray(table[-1]))


@pytest.mark.parametrize("backend", ["ref", "perf"])
def test_vocab_kernel_backend(backend):
    rng = np.random.default_rng(2)
    keys = rng.choice(2**31, 2000, replace=False).astype(np.uint32)
    vocab = DictionaryVocab(keys)
    rows, found = vocab.encode(jnp.asarray(keys[100:200]), backend=backend)
    assert bool(jnp.all(found))
    assert bool(jnp.all(rows == jnp.arange(100, 200)))


def test_qr_embedding_shapes_and_determinism():
    params = init_qr(jax.random.PRNGKey(0), num_rows=1_000_000, d=16, r_r=512)
    ids = jnp.asarray([3, 999_999, 3, 12345], jnp.uint32)
    out = qr_embedding(params, ids, 1_000_000)
    assert out.shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[2]))
    assert not np.allclose(np.asarray(out[0]), np.asarray(out[1]))
