"""Reserved pad/sentinel key domain enforcement (ISSUE 6 bugfix).

A user key equal to ROUTE_PAD (0xFFFFFFF0) used to be accepted by the
engine and then silently treated as routing padding by the sharded RLU
paths: never stored, probes always miss, no error anywhere.  The fix
closes the key domain at the engine/tenancy boundary with real
ValueErrors (not asserts — they must survive ``python -O``):

  * submit() rejects any op whose key (or scan range end) reaches the
    reserved range [0xFFFFFFF0, 0xFFFFFFFF] — through BOTH shard
    backends (host shard list and mesh/shard_map);
  * preload() rejects reserved keys the same way;
  * tenanted keys are bounded by the tenant key space instead (folding
    keeps them below the reserved floor; TenantSpace.fold double-checks);
  * the highest usable key 0xFFFFFFEF still round-trips
    insert -> probe -> delete normally on both backends.
"""
import numpy as np
import pytest

from repro.configs.base import HashMemConfig
from repro.core.rlu import ROUTE_PAD
from repro.serving import PAD_KEY, Request, ServingEngine, TenantRegistry

RESERVED = (0xFFFFFFF0, 0xFFFFFFFE, 0xFFFFFFFF)   # ROUTE_PAD, TOMBSTONE, EMPTY
TOP_OK = 0xFFFFFFEF                               # highest usable key


def _cfg():
    return HashMemConfig(num_buckets=32, slots_per_page=16,
                         overflow_pages=32, max_chain=8, backend="ref")


def _engines():
    """One engine per shard backend: host shard list and in-process mesh."""
    from repro.launch.mesh import make_serving_mesh
    yield "host", ServingEngine(_cfg(), max_slots=4, num_shards=2)
    yield "mesh", ServingEngine(_cfg(), max_slots=4,
                                mesh=make_serving_mesh(1))


def test_pad_key_is_route_pad():
    # the engine's reserved floor IS the RLU routing pad sentinel
    assert int(PAD_KEY) == int(ROUTE_PAD) == 0xFFFFFFF0


def test_submit_rejects_reserved_keys_both_backends():
    for backend, eng in _engines():
        for key in RESERVED:
            for op in (("read", key), ("insert", key, 1),
                       ("update", key, 1), ("delete", key),
                       ("rmw", key, 1)):
                with pytest.raises(ValueError, match="reserved"):
                    eng.submit(Request(ops=[op]))
        # a scan that STARTS below the floor but reaches into it
        with pytest.raises(ValueError, match="reserved"):
            eng.submit(Request(ops=[("scan", int(PAD_KEY) - 2, 8)]))
        # nothing was admitted or queued by the rejected submits
        st = eng.stats()
        assert st["occupancy"] == 0 and st["pending"] == 0, backend


def test_top_usable_key_roundtrips_both_backends():
    for backend, eng in _engines():
        r1 = Request(ops=[("insert", TOP_OK, 77), ("read", TOP_OK)])
        eng.submit(r1)
        eng.run()
        assert r1.results[0]["ok"], backend
        assert r1.results[1]["found"] and r1.results[1]["value"] == 77, \
            backend
        r2 = Request(ops=[("delete", TOP_OK), ("read", TOP_OK)])
        eng.submit(r2)
        eng.run()
        assert r2.results[0]["found"], backend
        assert not r2.results[1]["found"], backend


def test_preload_rejects_reserved_keys():
    for backend, eng in _engines():
        for key in RESERVED:
            ks = np.array([1, 2, key], dtype=np.uint32)
            with pytest.raises(ValueError, match="reserved"):
                eng.preload(ks, np.arange(3, dtype=np.uint32))
        # boundary: the floor itself is rejected, one below is fine
        with pytest.raises(ValueError, match="reserved"):
            eng.preload(np.array([int(PAD_KEY)], np.uint32),
                        np.array([1], np.uint32))
        eng.preload(np.array([TOP_OK], np.uint32),
                    np.array([5], np.uint32))
        r = Request(ops=[("read", TOP_OK)])
        eng.submit(r)
        eng.run()
        assert r.results[0]["found"] and r.results[0]["value"] == 5, backend


def test_tenant_keys_bounded_by_tenant_space():
    reg = TenantRegistry()
    t = reg.register("T")
    eng = ServingEngine(_cfg(), max_slots=4, tenants=reg)
    # tenant keys are validated against the (smaller) tenant key space,
    # long before they could reach the reserved range post-folding
    with pytest.raises(ValueError):
        eng.submit(Request(ops=[("read", reg.space.key_space)], tenant=t))
    with pytest.raises(ValueError):
        eng.submit(Request(ops=[("insert", 0xFFFFFFF0, 1)], tenant=t))
    ok = Request(ops=[("insert", reg.space.key_space - 1, 3),
                      ("read", reg.space.key_space - 1)], tenant=t)
    eng.submit(ok)
    eng.run()
    assert ok.results[1]["found"] and ok.results[1]["value"] == 3


def test_page_table_alloc_rejects_reserved_keys():
    """Decode-path regression: the paged-KV page-table allocator derives
    hashmap keys as seq_id * MAX_BLOCKS + block, so a large seq_id (or a
    long sequence under one) used to walk the key into the reserved
    pad/sentinel range and the block silently became unprobeable.  The
    shared validate_user_keys check now rejects the request BEFORE any
    page is claimed."""
    from repro.core.paged_kv import PageTableManager

    pt = PageTableManager(total_pages=16, num_channels=2)
    free_before = [len(a) for a in pt.free]
    mb = PageTableManager.MAX_BLOCKS

    # the last block's key lands exactly on the reserved floor
    seq_hot = 0xFFFFFFF0 // mb                      # key(seq, 4080) == floor
    with pytest.raises(ValueError, match="reserved"):
        pt.alloc_seqs([(seq_hot, (0xFFFFFFF0 % mb) + 1, 0)])
    # a seq_id whose FIRST key already overflows uint32 entirely
    with pytest.raises(ValueError, match="reserved"):
        pt.alloc_seq((1 << 32) // mb, 1)
    # rejection in a coalesced batch: the valid sibling is not admitted
    # either and, crucially, NO page leaked from any arena
    with pytest.raises(ValueError, match="reserved"):
        pt.alloc_seqs([(3, 2, 0), (seq_hot, (0xFFFFFFF0 % mb) + 1, 0)])
    assert [len(a) for a in pt.free] == free_before
    assert pt.owned == {}

    # the same large seq_id allocates fine while its keys stay below the
    # floor (key(seq_hot, 0) = 0xFFFFF000), as does the valid sibling
    tbl = pt.alloc_seqs([(seq_hot, 1, 0), (3, 2, 0)])
    assert len(tbl[seq_hot]) == 1 and len(tbl[3]) == 2


def test_unknown_op_kind_rejected():
    eng = ServingEngine(_cfg(), max_slots=4)
    with pytest.raises(ValueError, match="unknown op kind"):
        eng.submit(Request(ops=[("upsert", 1, 2)]))
