"""Serving integration: continuous batching with the HashMem page table."""
import pytest

from repro.configs import smoke_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import serve


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_serve_drains_all_requests(mesh):
    cfg = smoke_config("llama3-8b")
    done, mgr, steps_run = serve(cfg, mesh, batch=2, requests=5, max_new=4,
                                 horizon=64, page_tokens=16, backend="ref",
                                 verbose=False)
    assert len(done) == 5
    assert all(len(r["out"]) == 4 for r in done)
    assert mgr.live_pages() == 0          # every page tombstoned + recycled
    assert all(len(arena) > 0 for arena in mgr.free)


def test_serve_with_pallas_backend(mesh):
    cfg = smoke_config("qwen3-8b")
    done, mgr, _ = serve(cfg, mesh, batch=2, requests=3, max_new=3,
                         horizon=64, page_tokens=16, backend="perf",
                         verbose=False)
    assert len(done) == 3


def test_serve_deterministic_outputs(mesh):
    cfg = smoke_config("llama3-8b")
    d1, _, _ = serve(cfg, mesh, batch=2, requests=3, max_new=4, horizon=64,
                     page_tokens=16, verbose=False, seed=5)
    d2, _, _ = serve(cfg, mesh, batch=2, requests=3, max_new=4, horizon=64,
                     page_tokens=16, verbose=False, seed=5)
    for a, b in zip(sorted(d1, key=lambda r: r["id"]),
                    sorted(d2, key=lambda r: r["id"])):
        assert a["out"] == b["out"]
