"""Serving engine: coalescing, admission control, differential correctness.

The coalescing contract (ISSUE 4) is asserted two ways:

  * engine-level — ``calls_last_tick`` counts the HashMem API calls a tick
    issued: with coalescing ON it is at most one per op phase per shard, no
    matter how many requests fed the tick;
  * jaxpr-level — the ``scatters_per_insert`` counter (count_scatters) shows
    the batched insert costs a CONSTANT 3 pool scatters regardless of batch
    size, so a coalesced tick's insert scatter cost is 3 while the
    per-request baseline pays 3 per op.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.core.introspect import count_scatters
from repro.serving import (MetricsCollector, Request, ServingEngine,
                           TenantRegistry)

from model import DictModel


def _cfg(**kw):
    base = dict(num_buckets=32, slots_per_page=16, overflow_pages=32,
                max_chain=8, backend="ref")
    base.update(kw)
    return HashMemConfig(**base)


def _engine(**kw):
    kw.setdefault("max_slots", 8)
    cfg = kw.pop("cfg", _cfg())
    return ServingEngine(cfg, **kw)


# ---------------------------------------------------------------------------
# Coalescing
# ---------------------------------------------------------------------------

def test_one_batched_call_per_phase_per_tick():
    """16 concurrent inserting requests -> ONE insert call in the tick."""
    eng = _engine(max_slots=16)
    eng.submit_all([Request(ops=[("insert", k, k + 1), ("read", k)])
                    for k in range(16)])
    eng.tick()
    assert eng.calls_last_tick == {"probe": 0, "delete": 0, "insert": 1, "fused_tick": 0}
    eng.tick()
    assert eng.calls_last_tick == {"probe": 1, "delete": 0, "insert": 0, "fused_tick": 0}
    # jaxpr-traced counter: the coalesced call is 3 pool scatters TOTAL,
    # i.e. constant in the number of coalesced requests
    keys = jnp.arange(16, dtype=jnp.uint32)
    hm = hashmap.create(_cfg())
    assert count_scatters(hashmap.insert, hm, keys, keys) == 3
    assert count_scatters(hashmap.insert, hm, keys[:1], keys[:1]) == 3


def test_mixed_tick_at_most_one_call_per_phase_per_shard():
    for shards in (1, 2):
        eng = _engine(max_slots=12, num_shards=shards)
        eng.preload(np.arange(32, dtype=np.uint32),
                    np.arange(32, dtype=np.uint32) + 7)
        reqs = [Request(ops=[("read", k)]) for k in range(4)] + \
               [Request(ops=[("update", k, 99)]) for k in range(4, 8)] + \
               [Request(ops=[("delete", k)]) for k in range(8, 10)] + \
               [Request(ops=[("rmw", k, 5)]) for k in range(10, 12)]
        eng.submit_all(reqs)
        eng.tick()
        for kind in ("probe", "delete", "insert"):
            assert 1 <= eng.calls_last_tick[kind] <= shards, \
                (shards, kind, eng.calls_last_tick)


def test_per_request_baseline_calls_scale_with_requests():
    eng = _engine(max_slots=16, coalesce=False)
    eng.submit_all([Request(ops=[("insert", k, k + 1)]) for k in range(16)])
    eng.tick()
    assert eng.calls_last_tick["insert"] == 16


def test_coalesced_equals_per_request_results():
    """Identical request stream, identical per-request results either way
    (fixed phase order; distinct keys within a tick)."""
    def build(coalesce):
        eng = _engine(max_slots=4, coalesce=coalesce)
        eng.preload(np.arange(16, dtype=np.uint32),
                    np.arange(16, dtype=np.uint32) * 10)
        reqs = [
            Request(ops=[("read", 0), ("update", 0, 111), ("read", 0)]),
            Request(ops=[("rmw", 1, 222), ("read", 1), ("delete", 1)]),
            Request(ops=[("scan", 2, 4), ("insert", 100, 7), ("read", 100)]),
            Request(ops=[("read", 15), ("delete", 15), ("read", 15)]),
            Request(ops=[("read", 3), ("read", 100), ("scan", 0, 3)]),
        ]
        eng.submit_all(reqs)
        eng.run()
        return [r.results for r in reqs]

    a, b = build(True), build(False)
    assert a == b


# ---------------------------------------------------------------------------
# Differential: engine semantics vs the dict model
# ---------------------------------------------------------------------------

def test_engine_differential_vs_dict_model():
    """Random single-op requests (distinct keys per tick) replayed against
    DictModel, which encodes the exact HashMem semantics: update is
    tombstone-oldest + append, probe returns the oldest duplicate."""
    rng = np.random.default_rng(7)
    eng = _engine(max_slots=6, cfg=_cfg(num_buckets=16, overflow_pages=48))
    m = DictModel()
    keys0 = np.arange(24, dtype=np.uint32)
    vals0 = rng.integers(1, 2**31, 24).astype(np.uint32)
    eng.preload(keys0, vals0)
    m.insert(keys0, vals0, np.ones(24, bool))

    for round_ in range(30):
        ks = rng.choice(40, size=6, replace=False)
        reqs = []
        for k in ks:
            kind = rng.choice(["read", "update", "insert", "delete", "rmw"])
            v = int(rng.integers(1, 2**31))
            if kind == "read":
                reqs.append(Request(ops=[("read", int(k))]))
            elif kind == "delete":
                reqs.append(Request(ops=[("delete", int(k))]))
            elif kind == "insert":
                reqs.append(Request(ops=[("insert", int(k), v)]))
            elif kind == "update":
                reqs.append(Request(ops=[("update", int(k), v)]))
            else:
                reqs.append(Request(ops=[("rmw", int(k), v)]))
        eng.submit_all(reqs)
        eng.tick()
        # mirror the tick's phase order on the model: probe, delete, insert
        expected = {}
        for r in reqs:
            op = r.ops[0]
            if op[0] in ("read", "rmw"):
                ev, ef = m.probe([op[1]])
                expected[r.rid] = (ev[0], ef[0])
        for r in reqs:
            op = r.ops[0]
            if op[0] in ("delete", "update", "rmw"):
                m.delete([op[1]])
        for r in reqs:
            op = r.ops[0]
            if op[0] in ("insert", "update", "rmw"):
                m.insert([op[1]], [op[2]], [True])
        for r in reqs:
            res = r.results[0]
            op = r.ops[0]
            if op[0] == "read":
                ev, ef = expected[r.rid]
                assert res["found"] == ef and (not ef or res["value"] == ev)
            elif op[0] == "rmw":
                ev, ef = expected[r.rid]
                assert res["found"] == ef and (not ef or res["old"] == ev)
    st = hashmap.stats(eng.shards[0])
    assert st["live_entries"] == m.live_entries()


# ---------------------------------------------------------------------------
# Admission control + slot lifecycle
# ---------------------------------------------------------------------------

def test_admission_queue_and_reject():
    eng = _engine(max_slots=2, max_pending=3)
    outcomes = [eng.submit(Request(ops=[("read", 0)])) for _ in range(7)]
    assert outcomes == ["admitted", "admitted", "queued", "queued",
                        "queued", "rejected", "rejected"]
    snap = eng.run()
    assert snap["requests_completed"] == 5      # rejected ones never run
    assert eng.pool.idle()


def test_tenant_slot_quota_throttles_concurrency():
    reg = TenantRegistry()
    greedy = reg.register("greedy", max_slots=1)
    other = reg.register("other")
    eng = _engine(max_slots=4, tenants=reg)
    eng.submit_all([Request(ops=[("read", k), ("read", k)], tenant=greedy)
                    for k in range(4)])
    eng.submit_all([Request(ops=[("read", k)], tenant=other)
                    for k in range(3)])
    occ = []
    while not eng.pool.idle():
        eng.tick()
        occ.append(eng._active_by_tenant.get(greedy.tid, 0))
    assert max(occ) == 1                        # quota held every tick
    assert greedy.stats["completed"] == 4       # but all work drained
    assert other.stats["completed"] == 3


def test_tenant_pending_quota_rejects():
    reg = TenantRegistry()
    t = reg.register("t", max_slots=1, max_pending=2)
    eng = _engine(max_slots=4, tenants=reg)
    outcomes = [eng.submit(Request(ops=[("read", 0)], tenant=t))
                for _ in range(5)]
    assert outcomes == ["admitted", "queued", "queued",
                        "rejected", "rejected"]
    assert t.stats["rejected"] == 2


def test_slot_recycling_drains_backlog():
    eng = _engine(max_slots=3)
    n = 17
    eng.submit_all([Request(ops=[("insert", k, k)]) for k in range(n)])
    snap = eng.run()
    assert snap["requests_completed"] == n
    assert snap["occupancy"]["max"] == 3
    v, f = hashmap.probe(eng.shards[0],
                         jnp.arange(n, dtype=jnp.uint32))
    assert bool(jnp.all(f))


# ---------------------------------------------------------------------------
# Engine-tick compaction + metrics
# ---------------------------------------------------------------------------

def test_tick_clock_compaction_without_further_deletes():
    """Tombstones left by early deletes are reclaimed by the tick clock
    even though no later request ever deletes (the maybe_compact-on-free
    blind spot this PR fixes)."""
    eng = _engine(max_slots=8, compact_every=4,
                  cfg=_cfg(compact_tombstone_frac=0.0))
    keys = np.arange(16, dtype=np.uint32)
    eng.preload(keys, keys + 1)
    eng.submit_all([Request(ops=[("delete", int(k))]) for k in keys[:6]])
    eng.run()
    assert eng.compact_events == 0               # tick clock not reached yet
    assert hashmap.stats(eng.shards[0])["tombstones"] == 6
    # read-only traffic from here on — compaction must still fire
    eng.submit_all([Request(ops=[("read", int(k))] * 3)
                    for k in np.tile(keys[6:14], 2)])
    eng.run()
    assert eng.compact_events >= 1
    assert hashmap.stats(eng.shards[0])["tombstones"] == 0


def test_metrics_snapshot_contents():
    eng = _engine(max_slots=4, metrics=MetricsCollector(chain_sample_every=1))
    eng.preload(np.arange(8, dtype=np.uint32), np.arange(8, dtype=np.uint32))
    eng.submit_all([Request(ops=[("read", k % 8), ("update", k % 8, 5)])
                    for k in range(6)])
    snap = eng.run()
    assert snap["requests_completed"] == 6
    assert snap["total_ops"] == 12
    assert snap["probe_hit_rate"] == 1.0
    assert snap["request_latency_ticks"]["p99"] >= \
        snap["request_latency_ticks"]["p50"] >= 2
    assert snap["occupancy"]["max"] <= 4
    assert snap["chain_telemetry"], "chain sampling never ran"
    assert snap["op_counts"]["read"] == 6
    assert eng.stats()["tenants"] == {}


def test_scan_results():
    eng = _engine(max_slots=2)
    eng.preload(np.arange(10, dtype=np.uint32),
                np.arange(10, dtype=np.uint32) * 2)
    r = Request(ops=[("scan", 7, 5)])
    eng.submit(r)
    eng.run()
    res = r.results[0]
    assert res["values"][:3] == [14, 16, 18]
    assert res["found"] == [True, True, True, False, False]


@pytest.mark.parametrize("shards", [1, 3])
def test_sharded_engine_correctness(shards):
    eng = _engine(max_slots=4, num_shards=shards)
    keys = np.arange(30, dtype=np.uint32)
    eng.preload(keys, keys * 5)
    reqs = [Request(ops=[("read", int(k))]) for k in keys]
    eng.submit_all(reqs)
    eng.run()
    for k, r in zip(keys, reqs):
        assert r.results[0] == {"op": "read", "key": int(k),
                                "value": int(k) * 5, "found": True}


# ---------------------------------------------------------------------------
# Multi-tick op pipelining (metamorphic: pipelined == unpipelined, exactly)
# ---------------------------------------------------------------------------

def _strip_time(snap: dict) -> dict:
    """Deterministic slice of a metrics snapshot (wall-clock fields vary)."""
    return {k: snap[k] for k in
            ("ticks", "total_ops", "ops_per_tick", "requests_completed",
             "request_latency_ticks", "occupancy", "op_counts",
             "probe_hit_rate")}


def test_pipelined_results_and_metrics_equal_unpipelined():
    """Random mixed workloads (uniform AND zipfian-contended): pipeline
    depths 2 and 3 must reproduce the unpipelined run bit-for-bit — request
    results, the op->tick schedule itself, and every deterministic metric."""
    from model import make_engine_schedule

    for seed in range(10):
        streams = make_engine_schedule(seed, n_requests=16,
                                       ops_per_request=3, keyspace=32,
                                       zipf_theta=0.99 if seed % 2 else 0.0)

        def run(depth):
            eng = _engine(max_slots=8, pipeline_depth=depth,
                          record_schedule=True)
            eng.preload(np.arange(16, dtype=np.uint32),
                        np.arange(16, dtype=np.uint32) * 3)
            reqs = [Request(ops=list(o)) for o in streams]
            eng.submit_all(reqs)
            snap = eng.run()
            return [r.results for r in reqs], snap, eng

        r1, s1, e1 = run(1)
        for depth in (2, 3):
            rd, sd, ed = run(depth)
            assert rd == r1, (seed, depth)
            assert ed.schedule == e1.schedule, \
                (seed, depth, "op->tick schedule diverged")
            assert _strip_time(sd) == _strip_time(s1), (seed, depth)


def test_pipelined_read_your_writes_stalls_fence():
    """A read of a key whose insert is still in flight must stall the
    pipeline (write-claim fence), then observe the write — read-your-writes
    across pipelined ticks."""
    eng = _engine(max_slots=2, pipeline_depth=2)
    eng.preload(np.asarray([5], np.uint32), np.asarray([50], np.uint32))
    r = Request(ops=[("update", 5, 111), ("read", 5)])
    eng.submit(r)
    eng.run()
    assert r.results[1] == {"op": "read", "key": 5, "value": 111,
                            "found": True}
    assert eng.stall_events >= 1
    # non-conflicting traffic does NOT stall
    eng2 = _engine(max_slots=4, pipeline_depth=2)
    eng2.preload(np.arange(8, dtype=np.uint32), np.arange(8, dtype=np.uint32))
    eng2.submit_all([Request(ops=[("insert", 100 + k, k), ("read", k)])
                     for k in range(4)])
    eng2.run()
    assert eng2.stall_events == 0


def test_pipelined_tick_call_counts_unchanged():
    """A pipelined tick still issues at most one call per phase per shard —
    pipelining defers materialization, never splits batches."""
    eng = _engine(max_slots=16, pipeline_depth=2)
    eng.submit_all([Request(ops=[("insert", k, k + 1), ("read", 100 + k)])
                    for k in range(16)])
    eng.tick()
    assert eng.calls_last_tick == {"probe": 0, "delete": 0, "insert": 1, "fused_tick": 0}
    eng.tick()
    assert eng.calls_last_tick == {"probe": 1, "delete": 0, "insert": 0, "fused_tick": 0}
    assert eng.stats()["pipeline"]["depth"] == 2


def test_one_shard_grow_keeps_other_shards_tombstone_accounting():
    """A grow that rebuilds only shard 1 must not reset shard 0's tombstone
    counter (per-shard rebuild epochs) — otherwise repeated growth starves
    the tombstone-fraction compaction trigger on untouched shards."""
    from repro.core import rlu
    cfg = _cfg(num_buckets=8, slots_per_page=8, overflow_pages=8,
               max_chain=2, auto_grow=True)
    eng = ServingEngine(cfg, num_shards=2, max_slots=8, compact_every=10**6)
    owners = rlu.owner_of_np(np.arange(4096, dtype=np.uint32), cfg, 2,
                             eng.shard_by)
    k0 = np.nonzero(owners == 0)[0][:8].astype(np.uint32)
    k1 = np.nonzero(owners == 1)[0][:160].astype(np.uint32)
    eng.preload(k0, k0)
    eng.submit_all([Request(ops=[("delete", int(k))]) for k in k0[:4]])
    eng.run()
    assert eng._tombstones[0] == 4
    # flood shard 1 until its arena rebuilds
    eng.submit_all([Request(ops=[("insert", int(k), 1)]) for k in k1])
    eng.run()
    assert eng.grow_events >= 1
    assert eng.shards[1].config.num_buckets > cfg.num_buckets
    assert eng.shards[0].config.num_buckets == cfg.num_buckets
    assert eng._tombstones[0] == 4, "untouched shard's accounting was reset"
    assert eng._tombstones[1] == 0


# ---------------------------------------------------------------------------
# Mesh backend, single-device in-process slice (>= 2-device coverage lives
# in test_serving_sharded.py subprocesses)
# ---------------------------------------------------------------------------

def test_mesh_backend_single_device_matches_host():
    from repro.launch.mesh import make_serving_mesh
    from model import make_engine_schedule
    mesh = make_serving_mesh(1)
    streams = make_engine_schedule(3, n_requests=12, keyspace=24)

    def run(**kw):
        eng = _engine(max_slots=6, **kw)
        eng.preload(np.arange(12, dtype=np.uint32),
                    np.arange(12, dtype=np.uint32) * 7)
        reqs = [Request(ops=list(o)) for o in streams]
        eng.submit_all(reqs)
        eng.run()
        return [r.results for r in reqs], eng

    ref, _ = run()
    got, eng = run(mesh=mesh)
    assert got == ref
    assert eng.stats()["mesh_backed"]
    got2, eng2 = run(mesh=mesh, pipeline_depth=2)
    assert got2 == ref
    # the unfused mesh path agrees too, and keeps the per-phase contract
    got3, eng3 = run(mesh=mesh, fused_tick=False)
    assert got3 == ref
    assert not eng3.fused_tick
    # a tick with only inserts: fused default = ONE whole-tick launch;
    # fused_tick=False = exactly ONE rlu call for the non-empty phase
    for fused, want in ((None, {"probe": 0, "delete": 0, "insert": 0,
                                "fused_tick": 1}),
                        (False, {"probe": 0, "delete": 0, "insert": 1,
                                 "fused_tick": 0})):
        eng4 = _engine(max_slots=8, mesh=mesh, fused_tick=fused)
        eng4.submit_all([Request(ops=[("insert", k, k)]) for k in range(8)])
        eng4.tick()
        assert eng4.calls_last_tick == want, (fused, eng4.calls_last_tick)


def test_same_tick_write_contention_is_serialized():
    """Two updates of one key submitted in the same tick must behave like
    sequential updates (write-claim deferral): no leaked duplicate copies,
    and a later read sees the LAST writer's value.  Coalesced and
    per-request modes agree exactly."""
    def run(coalesce):
        eng = _engine(max_slots=8, coalesce=coalesce)
        eng.preload(np.asarray([5], np.uint32), np.asarray([50], np.uint32))
        r1 = Request(ops=[("update", 5, 111)])
        r2 = Request(ops=[("update", 5, 222)])
        eng.submit_all([r1, r2])
        eng.tick()                               # r2's update is deferred
        assert r1.results and not r2.results
        eng.run()
        eng.submit(Request(ops=[("update", 5, 333)]))
        eng.run()
        r4 = Request(ops=[("read", 5)])          # next tick: read-your-writes
        eng.submit(r4)
        eng.run()
        live = hashmap.stats(eng.shards[0])["live_entries"]
        return r4.results[0], live

    for coalesce in (True, False):
        res, live = run(coalesce)
        assert live == 1, "same-tick updates leaked duplicate copies"
        assert res == {"op": "read", "key": 5, "value": 333, "found": True}

    # same-tick duplicate DELETES: exactly one removal per live copy,
    # second delete observes the first (found=False once emptied)
    eng = _engine(max_slots=8)
    eng.preload(np.asarray([9], np.uint32), np.asarray([90], np.uint32))
    d1 = Request(ops=[("delete", 9)])
    d2 = Request(ops=[("delete", 9)])
    eng.submit_all([d1, d2])
    eng.run()
    assert d1.results[0]["found"] is True
    assert d2.results[0]["found"] is False
    assert hashmap.stats(eng.shards[0])["live_entries"] == 0
