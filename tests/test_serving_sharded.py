"""Device-parallel serving: the mesh-backed ServingEngine on >= 2 forced
host devices (ISSUE 5 acceptance).

Every test runs in a SUBPROCESS with
``--xla_force_host_platform_device_count`` so the main pytest process keeps
the single real CPU device (same pattern as test_distributed.py); the
subprocess imports the driver from tests/sharded_driver.py.

Covered here:

  * call-count acceptance — a coalesced tick on a >= 2-device mesh is ONE
    fused rlu.tick_mesh launch for ALL phases (the whole-tick megakernel,
    the default), or exactly one probe/delete/insert call per phase with
    ``fused_tick=False`` (engine counters); the fused launch lowers to
    exactly ONE shard_map and a fixed all_to_all budget no matter the
    batch size (jaxpr-level, core.introspect.count_primitive);
  * two-pass skew-aware routing — the fused tick's per-(src,dst) routing
    capacity follows the measured key skew (jaxpr buffer shapes change
    with the DATA, not just the batch shape; introspect.primitive_shapes),
    never truncates under adversarial all-keys-to-one-shard skew, and
    stays <= the worst-case Q_local padding;
  * the sharded differential sweep — 200+ randomized mixed schedules
    (uniform AND zipfian-contended), each run fused and unfused, with
    pipelining off and on (and periodically per-request), bit-compared
    against the host-shard reference and replayed op-for-op against the
    DictModel, with per-shard ownership/population invariants;
  * fault injection — a request killed between pipelined ticks (slot
    reclamation, no ghost ops) and synchronized growth forced inside a
    pipelined window (no lost or duplicated keys).
"""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 2, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests")])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_mesh_tick_exactly_one_call_per_phase():
    """16 mixed requests on a 2-device mesh: ONE fused launch for the whole
    tick by default, one backend call per op phase with fused_tick=False —
    versus one call per op in per-request mode."""
    run_sub("""
        import numpy as np
        from sharded_driver import _cfg
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import Request, ServingEngine
        mesh = make_serving_mesh()
        ZERO = {"probe": 0, "delete": 0, "insert": 0, "fused_tick": 0}
        reqs = lambda: [Request(ops=[("read", k)]) for k in range(6)] + \\
               [Request(ops=[("update", k, 99)]) for k in range(6, 10)] + \\
               [Request(ops=[("delete", k)]) for k in range(10, 13)] + \\
               [Request(ops=[("rmw", k, 5)]) for k in range(13, 16)]
        # DEFAULT: coalesced mesh tick is the fused megakernel — ONE launch
        # for probe+delete+insert, zero per-phase calls
        eng = ServingEngine(_cfg(), mesh=mesh, max_slots=16)
        assert eng.fused_tick
        eng.preload(np.arange(32, dtype=np.uint32),
                    np.arange(32, dtype=np.uint32) + 7)
        eng.submit_all(reqs())
        eng.tick()
        assert eng.calls_last_tick == dict(ZERO, fused_tick=1), \\
            eng.calls_last_tick
        # fused_tick=False: the three-call per-phase contract still holds
        engu = ServingEngine(_cfg(), mesh=mesh, max_slots=16,
                             fused_tick=False)
        engu.preload(np.arange(32, dtype=np.uint32),
                     np.arange(32, dtype=np.uint32) + 7)
        engu.submit_all(reqs())
        engu.tick()
        assert engu.calls_last_tick == dict(ZERO, probe=1, delete=1,
                                            insert=1), engu.calls_last_tick
        # pipelined fused tick: still one launch per tick, phases or not
        eng2 = ServingEngine(_cfg(), mesh=mesh, max_slots=16,
                             pipeline_depth=2)
        eng2.preload(np.arange(32, dtype=np.uint32),
                     np.arange(32, dtype=np.uint32) + 7)
        eng2.submit_all([Request(ops=[("update", k, 1), ("read", k + 20)])
                         for k in range(16)])
        eng2.tick()
        assert eng2.calls_last_tick == dict(ZERO, fused_tick=1)
        eng2.tick()
        assert eng2.calls_last_tick == dict(ZERO, fused_tick=1)
        # per-request baseline: calls scale with ops
        eng3 = ServingEngine(_cfg(), mesh=mesh, max_slots=16, coalesce=False)
        eng3.preload(np.arange(32, dtype=np.uint32),
                     np.arange(32, dtype=np.uint32) + 7)
        eng3.submit_all([Request(ops=[("read", k)]) for k in range(16)])
        eng3.tick()
        assert eng3.calls_last_tick["probe"] == 16
        assert eng3.calls_last_tick["fused_tick"] == 0
        print("OK")
        """)


def test_mesh_phase_is_one_shard_map_jaxpr():
    """jaxpr-level: one coalesced phase call is exactly ONE shard_map (and
    2/3 routed all_to_all hops), constant in the batch size."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from sharded_driver import _cfg
        from repro.core import hashmap, rlu
        from repro.core.introspect import count_primitive
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh()
        cfg = _cfg()
        D = mesh.shape["model"]
        shards = [hashmap.create(cfg) for _ in range(D)]
        hm = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
        for Q in (D * 8, D * 64):
            q = jnp.zeros((Q,), jnp.uint32)
            v = jnp.zeros((Q,), jnp.uint32)
            probe = lambda hm, q: rlu.probe_sharded(
                mesh, hm, q, cfg, shard_by="highbits")
            dele = lambda hm, q: rlu.delete_sharded(
                mesh, hm, q, cfg, shard_by="highbits")
            ins = lambda hm, q, v: rlu.insert_mesh(
                mesh, hm, q, v, cfg, shard_by="highbits")
            assert count_primitive(probe, "shard_map", hm, q) == 1
            assert count_primitive(dele, "shard_map", hm, q) == 1
            assert count_primitive(ins, "shard_map", hm, q, v) == 1
            # routed hops: query out + result back (values+found / found / ok)
            assert count_primitive(probe, "all_to_all", hm, q) == 3
            assert count_primitive(dele, "all_to_all", hm, q) == 2
            assert count_primitive(ins, "all_to_all", hm, q, v) == 3
        print("OK")
        """)


def test_fused_tick_is_one_shard_map_jaxpr():
    """jaxpr-level megakernel contract: the whole fused tick — probe +
    delete + insert — lowers to exactly ONE shard_map, constant in the
    batch size, with a fixed all_to_all budget (1 count exchange + 3 probe
    + 2 delete + 3 insert = 9 hops)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from sharded_driver import _cfg
        from repro.core import hashmap, rlu
        from repro.core.introspect import count_primitive
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh()
        cfg = _cfg()
        D = mesh.shape["model"]
        shards = [hashmap.create(cfg) for _ in range(D)]
        hm = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
        for Q in (D * 8, D * 64):
            pq = jnp.zeros((Q,), jnp.uint32)
            dq = jnp.zeros((Q,), jnp.uint32)
            ik = jnp.zeros((Q,), jnp.uint32)
            iv = jnp.zeros((Q,), jnp.uint32)
            tick = lambda hm, pq, dq, ik, iv: rlu.tick_mesh(
                mesh, hm, pq, dq, ik, iv, cfg, shard_by="highbits")
            n_sm = count_primitive(tick, "shard_map", hm, pq, dq, ik, iv)
            assert n_sm == 1, f"fused tick must be ONE shard_map, got {n_sm}"
            n_a2a = count_primitive(tick, "all_to_all", hm, pq, dq, ik, iv)
            assert n_a2a == 9, f"fused tick all_to_all budget: {n_a2a} != 9"
        print("OK")
        """)


def test_fused_routing_capacity_is_data_dependent():
    """Two-pass routing: two batches of the SAME shape but different key
    skew trace to DIFFERENT all_to_all buffer shapes (pass 1 measures the
    per-(src,dst) histogram and bakes the cap into the program), and a
    uniform batch's cap sits well under the worst-case Q_local padding."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from sharded_driver import _cfg, keys_owned_by
        from repro.core import hashmap, rlu
        from repro.core.introspect import primitive_shapes
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh()
        cfg = _cfg()
        D = mesh.shape["model"]
        shards = [hashmap.create(cfg) for _ in range(D)]
        hm = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
        Q = D * 64
        ql = Q // D
        # same SHAPE, different DATA: uniform spread vs all keys owned by
        # shard 0 (sourced evenly, so every src sends its whole slice there)
        rng = np.random.default_rng(0)
        uni = rng.integers(0, 1 << 31, Q).astype(np.uint32)
        skew = keys_owned_by(0, Q, cfg, D, shard_by="highbits")
        caps = {}
        shapes = {}
        for name, keys in (("uniform", uni), ("skewed", skew)):
            cap = rlu.routing_cap(keys, cfg, D, shard_by="highbits",
                                  quantum=1)
            caps[name] = cap
            pq = jnp.asarray(keys)
            z = jnp.zeros((Q,), jnp.uint32)
            tick = lambda hm, pq, dq, ik, iv: rlu.tick_mesh(
                mesh, hm, pq, dq, ik, iv, cfg, shard_by="highbits",
                caps=(cap, cap, cap))
            shapes[name] = primitive_shapes(tick, "all_to_all",
                                            hm, pq, z, z, z)
        # pass 1 (host histogram) saw the skew: capacities differ even
        # though both batches have identical shape/dtype
        assert caps["skewed"] == ql, caps
        assert caps["uniform"] < ql, caps
        # ... and that difference is STRUCTURAL in the lowered program:
        # the routed all_to_all buffers have different shapes per batch
        assert shapes["uniform"] != shapes["skewed"], shapes
        print("OK caps", caps)
        """)


def test_fused_worst_skew_never_truncates():
    """Adversarial all-keys-to-one-shard workload through the fused engine:
    results stay bit-identical to the host reference (nothing truncated)
    and every logged routing cap covers the measured per-(src,dst) max."""
    run_sub("""
        from sharded_driver import fused_worst_skew
        fused_worst_skew()
        """)


def test_fused_tick_tiny_batches():
    """Q_local in {1, 4}: the routing cap's quantum floor must clamp to the
    Q_local ceiling LAST (a cap above Q_local would trace an all_to_all
    buffer larger than the (D, Q_local) source slice), and the fused tick
    at those shapes stays bit-identical to the single-table reference."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from sharded_driver import _cfg
        from repro.core import hashmap, rlu
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh()
        cfg = _cfg()
        D = mesh.shape["model"]
        rng = np.random.default_rng(5)
        for q_local in (1, 4):
            Q = D * q_local
            keys = rng.integers(1, 1 << 31, Q).astype(np.uint32)
            for sb in ("highbits", "mod"):
                cap = rlu.routing_cap(keys, cfg, D, shard_by=sb)
                # quantum floor (8) first, Q_local ceiling last -> a tiny
                # batch caps at exactly min(8, Q_local)
                assert cap == min(8, q_local), (q_local, sb, cap)
            # fused tick at the tiny shape: insert, then probe + delete
            shards = [hashmap.create(cfg) for _ in range(D)]
            hm = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
            vals = (keys * 7 + 1).astype(np.uint32)
            pad = jnp.full((Q,), rlu.ROUTE_PAD, jnp.uint32)
            hm, _, _, _, ok = rlu.tick_mesh(
                mesh, hm, pad, pad, jnp.asarray(keys), jnp.asarray(vals),
                cfg, shard_by="highbits")
            assert bool(np.asarray(ok).all())
            hm, pv, pf, df, _ = rlu.tick_mesh(
                mesh, hm, jnp.asarray(keys), jnp.asarray(keys[::-1].copy()),
                pad, pad, cfg, shard_by="highbits")
            assert bool(np.asarray(pf).all())
            assert bool(np.asarray(df).all())
            np.testing.assert_array_equal(np.asarray(pv), vals)
            # deletes landed: a second probe finds nothing
            _, _, pf2, _, _ = rlu.tick_mesh(
                mesh, hm, jnp.asarray(keys), pad, pad, pad, cfg,
                shard_by="highbits")
            assert not bool(np.asarray(pf2).any())
        print("OK")
        """)


def test_split_during_pipelined_schedule():
    """Extendible-resize acceptance: an insert-heavy zipfian stream on a
    2-device mesh with pipeline depth 2 forces >= 2 group splits
    mid-pipeline; results stay bit-identical to the host reference and the
    DictModel replay, with ZERO full-rebuild grow events (the same driver
    `make grow-smoke` runs, plus trace-level span assertions there)."""
    run_sub("""
        from sharded_driver import grow_smoke
        grow_smoke()
        """)


def test_sharded_differential_sweep_block0():
    """100+ randomized schedules, pipelining off and on, uniform+zipfian."""
    run_sub("""
        from sharded_driver import sweep
        sweep(seed0=3000, n=104, depths=(2,))
        """)


def test_sharded_differential_sweep_block1():
    """Second 100-schedule block: deeper pipeline, 4 devices."""
    run_sub("""
        from sharded_driver import sweep
        sweep(seed0=4000, n=104, depths=(2, 3))
        """, devices=4)


def test_grow_during_pipelined_window():
    run_sub("""
        from sharded_driver import grow_under_pipeline
        grow_under_pipeline()
        """)


def test_kill_request_mid_pipeline():
    run_sub("""
        from sharded_driver import kill_mid_pipeline
        kill_mid_pipeline()
        """)
