"""End-to-end system behaviour: the paper's full pipeline in miniature —
build a HashMem, probe it through every backend, serve a model whose KV
page table is that HashMem, and train the same model family."""
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.data.kv_synth import kv_dataset, probe_set


def test_paper_microbenchmark_miniature():
    """Paper §4.1.1 scaled: N pairs, 10% random probes, all found."""
    n = 50_000
    keys, vals = kv_dataset(n, seed=0)
    cfg = HashMemConfig(num_buckets=1 << 8, slots_per_page=512,
                        overflow_pages=1 << 7, max_chain=4, backend="ref")
    chk = hashmap.build_check(cfg, keys)
    assert chk["fits"], chk
    hm = hashmap.build(cfg, jnp.asarray(keys), jnp.asarray(vals))
    q, idx = probe_set(keys, 0.1)
    v, f = hashmap.probe(hm, jnp.asarray(q))
    assert bool(jnp.all(f))
    np.testing.assert_array_equal(np.asarray(v), vals[idx])


def test_full_stack_train_then_serve(tmp_path):
    from repro.configs.base import OptimConfig, ShapeConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import serve
    from repro.launch.train import train

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = smoke_config("h2o-danube-1.8b")
    oc = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    shape = ShapeConfig("t", 64, 2, "train")
    train(cfg, shape, oc, mesh, num_steps=10, ckpt_dir=str(tmp_path),
          ckpt_every=0, verbose=False)
    done, mgr, _ = serve(cfg, mesh, batch=2, requests=3, max_new=3,
                         horizon=64, page_tokens=16, verbose=False)
    assert len(done) == 3 and mgr.live_pages() == 0
