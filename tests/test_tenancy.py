"""Tenant key-space folding and cross-tenant isolation.

The isolation property under test (ISSUE 4): tenant A's deletes, tombstone
churn, and table GROWTH (auto-grow rebuilds re-bucket every live entry)
never perturb tenant B's probe results — isolation is structural (disjoint
folded key ranges), not scheduling luck.
"""
import numpy as np
import pytest

from repro.configs.base import HashMemConfig
from repro.core import hashmap
from repro.serving import Request, ServingEngine, TenantRegistry
from repro.serving.tenancy import TenantSpace


# ---------------------------------------------------------------------------
# Key folding
# ---------------------------------------------------------------------------

def test_fold_unfold_roundtrip():
    sp = TenantSpace(bits=8)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, sp.key_space, 1000).astype(np.uint32)
    for tid in (0, 1, 17, sp.max_tenants - 1):
        folded = sp.fold(tid, keys)
        tids, raw = sp.unfold(folded)
        assert (tids == tid).all()
        assert (raw == keys).all()


def test_fold_disjoint_across_tenants():
    sp = TenantSpace(bits=8)
    rng = np.random.default_rng(1)
    keys = np.unique(rng.integers(0, sp.key_space, 4096).astype(np.uint32))
    seen = {}
    for tid in range(0, 24):
        for f in sp.fold(tid, keys):
            assert f not in seen, "folded collision across tenants"
            seen[f] = tid
    assert len(seen) == 24 * len(keys)


def test_fold_sentinel_safety():
    """No folded key may collide with EMPTY/TOMBSTONE or the PAD key."""
    sp = TenantSpace(bits=8)
    top = sp.fold(sp.max_tenants - 1, [sp.key_space - 1])[0]
    assert top < 0xFFFFFFF0
    with pytest.raises(ValueError):
        sp.fold(sp.max_tenants, [0])             # top id reserved
    with pytest.raises(ValueError):
        sp.fold(0, [sp.key_space])               # key too wide


def test_registry_assigns_distinct_ids():
    reg = TenantRegistry()
    a, b, c = reg.register("a"), reg.register("b"), reg.register(tid=7)
    assert {a.tid, b.tid, c.tid} == {0, 1, 7}
    d = reg.register("d")
    assert d.tid not in (a.tid, b.tid, c.tid)
    with pytest.raises(AssertionError):
        reg.register(tid=7)


# ---------------------------------------------------------------------------
# Isolation under churn + growth
# ---------------------------------------------------------------------------

def _read_all(eng, tenant, keys):
    reqs = [Request(ops=[("read", int(k))], tenant=tenant) for k in keys]
    eng.submit_all(reqs)
    eng.run()
    return [(r.results[0]["value"], r.results[0]["found"]) for r in reqs]


def test_tenant_isolation_under_deletes_and_growth():
    reg = TenantRegistry()
    a = reg.register("A")
    b = reg.register("B")
    # tiny pages + tight chain bound so tenant A's churn piles some bucket
    # past max_chain -> insert refusal -> a real grow() rebuild
    cfg = HashMemConfig(num_buckets=8, slots_per_page=4, overflow_pages=16,
                        max_chain=2, backend="ref", auto_grow=True,
                        max_load_factor=0.9)
    eng = ServingEngine(cfg, max_slots=8, tenants=reg)
    rng = np.random.default_rng(3)

    bkeys = np.arange(40, dtype=np.uint32)
    bvals = rng.integers(1, 2**31, 40).astype(np.uint32)
    eng.preload(bkeys, bvals, tenant=b)
    before = _read_all(eng, b, bkeys)
    assert all(f for _, f in before)
    assert [v for v, _ in before] == [int(v) for v in bvals]

    # tenant A: heavy insert/delete churn on OVERLAPPING raw key ids —
    # same raw ints as B's keys, different folded space
    for round_ in range(6):
        ks = rng.choice(64, size=8, replace=False)
        eng.submit_all(
            [Request(ops=[("insert", int(k), int(rng.integers(1, 2**31)))],
                     tenant=a) for k in ks[:5]]
            + [Request(ops=[("delete", int(k))], tenant=a) for k in ks[5:]])
        eng.run()
    assert eng.grow_events >= 1, "churn never forced a grow rebuild"

    after = _read_all(eng, b, bkeys)
    assert after == before, "tenant A's churn/growth perturbed tenant B"

    # and B's deletes only ever remove B's entries
    eng.submit_all([Request(ops=[("delete", int(k))], tenant=b)
                    for k in bkeys[:10]])
    eng.run()
    gone = _read_all(eng, b, bkeys[:10])
    assert not any(f for _, f in gone)
    a_live = hashmap.stats(eng.shards[0])["live_entries"]
    assert a_live > 0                            # A's entries untouched


def test_tenant_stats_exact_attribution_multi_shard():
    """Per-tenant op/hit accounting must be exact — attributed ONCE per
    executed op at gather time and once per probed key at writeback — no
    matter which shard an op routes to (ISSUE 5 audit: a per-phase-executor
    attribution would double-count update/rmw ops, which contribute entries
    to two phases, and scans spanning shards).  Also pins: deferred writers
    (same-tick claims) are counted once, on the tick they execute."""
    for shards, mesh_on in ((1, False), (3, False), (2, True)):
        reg = TenantRegistry()
        a = reg.register("A")
        b = reg.register("B")
        kw = {}
        if mesh_on:
            from repro.launch.mesh import make_serving_mesh
            kw["mesh"] = make_serving_mesh(1)     # in-process: 1 device
        eng = ServingEngine(HashMemConfig(num_buckets=32, slots_per_page=16,
                                          overflow_pages=32, max_chain=8,
                                          backend="ref"),
                            max_slots=8, tenants=reg,
                            num_shards=1 if mesh_on else shards, **kw)
        eng.preload(np.arange(16, dtype=np.uint32),
                    np.arange(16, dtype=np.uint32) * 2, tenant=a)
        # A: ops spreading across shards, incl. a scan and an rmw; the two
        # updates of key 0 land in the SAME tick, so the later slot's is
        # DEFERRED a tick but must still be counted exactly once
        eng.submit_all([
            Request(ops=[("update", 0, 9), ("read", 0)], tenant=a),
            Request(ops=[("scan", 1, 4)], tenant=a),
            Request(ops=[("rmw", 5, 7), ("read", 5)], tenant=a),
            Request(ops=[("update", 0, 11)], tenant=a),
        ])
        # B: misses only (its folded keyspace was never loaded)
        eng.submit_all([Request(ops=[("read", k)], tenant=b)
                        for k in range(3)])
        eng.run()
        st = reg.stats()
        assert st["A"]["ops"] == {"read": 2, "update": 2, "insert": 0,
                                  "delete": 0, "scan": 1, "rmw": 1}, \
            (shards, mesh_on, st["A"]["ops"])
        # hits: read0, scan 1-4 (4 hits), rmw5 pre-read, read5 = 7
        assert st["A"]["hits"] == 7 and st["A"]["misses"] == 0, \
            (shards, mesh_on, st["A"])
        assert st["B"]["ops"]["read"] == 3 and st["B"]["misses"] == 3
        assert st["A"]["completed"] == 4 and st["B"]["completed"] == 3
        # the table agrees: exactly one live copy of key 0 (two updates
        # serialized), value from the LAST writer
        va, fa = _read_all(eng, a, [0])[0]
        assert fa and va == 11, (shards, mesh_on, va)


def test_tenant_killed_attribution():
    reg = TenantRegistry()
    t = reg.register("T")
    eng = ServingEngine(HashMemConfig(num_buckets=32, slots_per_page=16,
                                      overflow_pages=32, max_chain=8,
                                      backend="ref"),
                        max_slots=2, tenants=reg)
    victim = Request(ops=[("insert", 1, 1), ("insert", 2, 2),
                          ("insert", 3, 3)], tenant=t)
    other = Request(ops=[("read", 1)], tenant=t)
    eng.submit_all([victim, other])
    eng.tick()
    assert eng.kill(victim)
    eng.run()
    st = reg.stats()["T"]
    assert st["killed"] == 1 and st["completed"] == 1
    # only the issued op counted; un-issued ops never attributed
    assert st["ops"]["insert"] == 1
    assert eng.stats()["killed_requests"] == 1


def test_tenant_stats_attribution():
    reg = TenantRegistry()
    a = reg.register("A")
    b = reg.register("B")
    eng = ServingEngine(HashMemConfig(num_buckets=32, slots_per_page=16,
                                      overflow_pages=32, max_chain=8,
                                      backend="ref"),
                        max_slots=4, tenants=reg)
    eng.preload(np.arange(8, dtype=np.uint32),
                np.arange(8, dtype=np.uint32), tenant=a)
    eng.submit_all([Request(ops=[("read", k)], tenant=a) for k in range(8)])
    eng.submit_all([Request(ops=[("read", k)], tenant=b) for k in range(4)])
    eng.run()
    st = reg.stats()
    assert st["A"]["ops"]["read"] == 8 and st["A"]["hits"] == 8
    # B reads the same raw ids but ITS folded keys were never inserted
    assert st["B"]["ops"]["read"] == 4 and st["B"]["misses"] == 4
    assert st["A"]["completed"] == 8 and st["B"]["completed"] == 4
