"""Trace-correctness tests (ISSUE 9): the exported Chrome trace-event JSON
is structurally valid (B/E pairs balance, timestamps monotonic per track),
spans nest, pipelined traces show OVERLAPPING tick spans on distinct lane
tracks while the op->tick schedule stays identical to the unpipelined
engine, a killed request emits its abort exactly once, and the ring bound
keeps tracer memory O(1).
"""
import json
import sys
from collections import defaultdict
from pathlib import Path

import numpy as np
import pytest

from repro.serving import Request, ServingEngine, Tracer
from repro.serving.tracing import NULL_TRACER, SPAN_NAMES

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from tools import trace_report  # noqa: E402


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def validate_events(events):
    """B/E balance + per-track ts monotonicity; returns completed spans as
    (name, tid, ts, dur) and asserts validity."""
    spans, _, problems = trace_report.validate(events)
    assert not problems, problems
    return spans


def run_engine(depth, trace=True, n_reqs=24, seed=3):
    rng = np.random.default_rng(seed)
    eng = ServingEngine(num_shards=2, max_slots=8, pipeline_depth=depth,
                        trace=trace, record_schedule=True)
    eng.preload(np.arange(64, dtype=np.uint32),
                np.arange(64, dtype=np.uint32))
    reqs = []
    for _ in range(n_reqs):
        k = int(rng.integers(0, 64))
        reqs.append(Request(ops=[("read", k), ("update", k, k + 1),
                                 ("read", k)]))
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, reqs


# ---------------------------------------------------------------------------
# Tracer unit behavior
# ---------------------------------------------------------------------------

def test_export_is_valid_chrome_trace(tmp_path):
    tr = Tracer()
    with tr.span("tick", tid=0, tick=0):
        with tr.span("gather", tid=0):
            pass
        with tr.span("writeback", tid=0):
            pass
    tr.counter("occupancy", 3)
    tr.instant("kill", rid=7)
    tr.async_begin("request", 1)
    tr.async_end("request", 1)
    path = tmp_path / "t.json"
    n = tr.export(str(path), note="unit")
    doc = json.loads(path.read_text())
    assert n == len(doc["traceEvents"])
    assert doc["otherData"]["note"] == "unit"
    assert doc["otherData"]["dropped"] == 0
    evs = doc["traceEvents"]
    validate_events(evs)
    phases = {e["ph"] for e in evs}
    assert {"B", "E", "C", "i", "b", "e", "M"} <= phases
    # global ts ordering (stable sort by ts)
    ts = [e["ts"] for e in evs if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_spans_nest_and_children_stay_inside_parent():
    tr = Tracer()
    outer = tr.begin("tick", 0)
    with tr.span("gather", 0):
        pass
    tr.end(outer)
    evs = [e for e in tr.to_events() if e["ph"] in "BE"]
    # nesting order on the single track: B tick, B gather, E gather, E tick
    assert [(e["ph"], e["name"]) for e in evs] == \
        [("B", "tick"), ("B", "gather"), ("E", "gather"), ("E", "tick")]


def test_ring_bound_and_dropped_counter():
    tr = Tracer(capacity=16)
    for i in range(100):
        tr.counter("tick_ops", i)
    assert len(tr) == 16
    assert tr.dropped == 84
    evs = tr.to_events()
    vals = [e["args"]["value"] for e in evs if e["ph"] == "C"]
    assert vals == [float(v) for v in range(84, 100)]  # newest survive


def test_ring_drops_never_unbalance_export():
    # spans are recorded as COMPLETED tuples, so dropping the oldest ring
    # entries can never orphan a B without its E
    tr = Tracer(capacity=8)
    for i in range(50):
        with tr.span("tick", tid=i % 3, tick=i):
            with tr.span("gather", tid=i % 3):
                pass
    validate_events(tr.to_events())


def test_unmatched_async_half_is_not_exported():
    tr = Tracer()
    tr.async_begin("request", 1)       # never ends (request still queued)
    tr.async_begin("request", 2)
    tr.async_end("request", 2)
    evs = tr.to_events()
    asy = [e for e in evs if e["ph"] in ("b", "e")]
    assert len(asy) == 2
    assert all(e["id"] == 2 for e in asy)


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("tick"):
        tr.counter("occupancy", 1)
        tr.instant("kill")
        tr.async_begin("request", 1)
        tr.async_end("request", 1)
    assert len(tr) == 0 and tr.dropped == 0
    assert NULL_TRACER.to_events() == []


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_engine_trace_valid_and_has_span_vocabulary(tmp_path):
    eng, _ = run_engine(depth=1)
    path = tmp_path / "eng.json"
    eng.export_trace(str(path))
    doc = json.loads(path.read_text())
    spans = validate_events(doc["traceEvents"])
    seen = {s[0] for s in spans}
    # the core per-tick vocabulary must appear on a host-shard run
    assert {"tick", "gather", "probe", "writeback", "admit",
            "preload"} <= seen
    assert seen <= set(SPAN_NAMES)
    assert doc["otherData"]["pipeline_depth"] == 1


def test_phase_spans_nest_inside_their_tick():
    eng, _ = run_engine(depth=1)
    spans = validate_events(eng.tracer.to_events())
    ticks = [(s[2], s[2] + s[3]) for s in spans if s[0] == "tick"]
    for name, tid, ts, dur, *_ in spans:
        if name in ("gather", "probe", "delete", "insert"):
            assert any(lo <= ts and ts + dur <= hi + 1e-3
                       for lo, hi in ticks), name


def test_pipelined_ticks_overlap_and_schedule_matches_unpipelined():
    eng1, _ = run_engine(depth=1)
    eng2, _ = run_engine(depth=2)
    eng3, _ = run_engine(depth=3)
    # identical op->tick schedules (pipelining must not change behavior)
    strip = [(t, k, keys, v) for t, k, keys, v, _ in eng1.schedule]
    for e in (eng2, eng3):
        assert [(t, k, keys, v) for t, k, keys, v, _ in e.schedule] == strip
    for eng in (eng2, eng3):
        spans = validate_events(eng.tracer.to_events())
        ticks = [s for s in spans if s[0] == "tick"]
        lanes = {s[1] for s in ticks}
        assert len(lanes) == eng.pipeline_depth      # one track per lane
        # at least one pair of tick spans overlaps in wall time (tick N+1
        # issued while tick N is still in flight on another lane)
        ivs = sorted((s[2], s[2] + s[3], s[1]) for s in ticks)
        overlaps = sum(1 for a, b in zip(ivs, ivs[1:])
                       if b[0] < a[1] and a[2] != b[2])
        assert overlaps >= 1, "no overlapping tick spans at depth>=2"


def test_stall_visible_in_pipelined_trace():
    # read-your-writes on a single hot key forces the write-claim fence
    eng = ServingEngine(num_shards=2, max_slots=4, pipeline_depth=2,
                        trace=True)
    for _ in range(6):
        eng.submit(Request(ops=[("update", 1, 9), ("read", 1),
                                ("update", 1, 10)]))
    eng.run()
    assert eng.stall_events >= 1
    spans = validate_events(eng.tracer.to_events())
    stalls = [s for s in spans if s[0] == "pipeline_stall"]
    assert len(stalls) == eng.stall_events


def test_killed_request_emits_abort_exactly_once():
    eng = ServingEngine(num_shards=1, max_slots=2, trace=True)
    live = Request(ops=[("read", 1)] * 6)
    victim = Request(ops=[("read", 2)] * 6)
    eng.submit(live)
    eng.submit(victim)
    eng.tick()
    assert eng.kill(victim)
    assert not eng.kill(victim)        # second kill is a no-op
    eng.run()
    evs = eng.tracer.to_events()
    kills = [e for e in evs if e["ph"] == "i" and e["name"] == "kill"]
    assert len(kills) == 1
    assert kills[0]["args"]["rid"] == victim.rid
    # the killed request's async lifecycle closed exactly once, with the
    # terminal status
    ends = [e for e in evs if e["ph"] == "e" and e["name"] == "request"
            and e["id"] == victim.rid]
    assert len(ends) == 1
    assert ends[0]["args"]["status"] == "killed"


def test_request_lifecycle_slices_balance():
    eng, reqs = run_engine(depth=2)
    evs = eng.tracer.to_events()
    per = defaultdict(lambda: defaultdict(int))
    for e in evs:
        if e["ph"] in ("b", "e"):
            per[(e["name"], e["id"])][e["ph"]] += 1
    for key, c in per.items():
        assert c["b"] == 1 and c["e"] == 1, (key, dict(c))
    # every completed request exported its request+queue+service slices
    names = defaultdict(set)
    for (name, rid), _ in per.items():
        names[rid].add(name)
    done = [r.rid for r in reqs if r.done()]
    assert done and all(names[rid] == {"request", "queue", "service"}
                        for rid in done)


def test_counter_tracks_emitted_per_tick():
    eng, _ = run_engine(depth=1)
    evs = eng.tracer.to_events()
    occ = [e for e in evs if e["ph"] == "C" and e["name"] == "occupancy"]
    ops = [e for e in evs if e["ph"] == "C" and e["name"] == "tick_ops"]
    assert len(occ) == eng.ticks and len(ops) == eng.ticks


def test_untraced_engine_matches_traced_results():
    eng_t, reqs_t = run_engine(depth=2, trace=True)
    eng_u, reqs_u = run_engine(depth=2, trace=False)
    assert [r.results for r in reqs_t] == [r.results for r in reqs_u]
    assert len(eng_u.tracer) == 0      # NULL_TRACER recorded nothing
    assert eng_u.tracer is NULL_TRACER


def test_tracer_instance_can_be_shared():
    tr = Tracer()
    eng = ServingEngine(num_shards=1, max_slots=2, trace=tr)
    assert eng.tracer is tr
    eng.submit(Request(ops=[("insert", 5, 6), ("read", 5)]))
    eng.run()
    assert len(tr) > 0


# ---------------------------------------------------------------------------
# trace_report CLI
# ---------------------------------------------------------------------------

def test_trace_report_cli_ok(tmp_path, capsys):
    eng, _ = run_engine(depth=2)
    path = tmp_path / "r.json"
    eng.export_trace(str(path))
    rc = trace_report.main([str(path), "--assert-spans",
                            "tick,gather,writeback,admit"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "per-phase breakdown" in out
    assert "slowest" in out
    assert "trace OK" in out


def test_trace_report_cli_fails_on_missing_span_or_stalls(tmp_path, capsys):
    tr = Tracer()
    with tr.span("tick", tick=0):
        pass
    path = tmp_path / "bare.json"
    tr.export(str(path))
    assert trace_report.main([str(path), "--assert-spans", "fused_tick"]) == 1
    assert trace_report.main([str(path), "--assert-stalls", "1"]) == 1
    assert trace_report.main([str(path)]) == 0
    capsys.readouterr()


def test_trace_report_flags_malformed_trace(tmp_path, capsys):
    bad = {"traceEvents": [
        {"name": "tick", "ph": "B", "pid": 1, "tid": 0, "ts": 10.0},
        {"name": "gather", "ph": "E", "pid": 1, "tid": 0, "ts": 12.0},
        {"name": "x", "ph": "B", "pid": 1, "tid": 1, "ts": 5.0},
    ]}
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(bad))
    assert trace_report.main([str(path)]) == 1
    out = capsys.readouterr().out
    assert "interleaved B/E" in out
    assert "unclosed B" in out


# ---------------------------------------------------------------------------
# profiler window hooks
# ---------------------------------------------------------------------------

def test_profiler_window_brackets_ticks(tmp_path, monkeypatch):
    import jax
    calls = []
    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop", None)))
    eng, _ = None, None
    eng = ServingEngine(num_shards=1, max_slots=4, trace=True)
    eng.profile_ticks(1, 3, str(tmp_path))
    for _ in range(8):
        eng.submit(Request(ops=[("insert", 3, 4), ("read", 3)]))
    eng.run()
    assert calls == [("start", str(tmp_path)), ("stop", None)]
    evs = eng.tracer.to_events()
    marks = [e["name"] for e in evs if e["ph"] == "i"
             and e["name"].startswith("profiler_")]
    assert marks == ["profiler_start", "profiler_stop"]


def test_profiler_backend_failure_is_survivable(tmp_path, monkeypatch):
    import jax

    def boom(_):
        raise RuntimeError("no profiler backend")
    monkeypatch.setattr(jax.profiler, "start_trace", boom)
    eng = ServingEngine(num_shards=1, max_slots=4, trace=True)
    eng.profile_ticks(0, 1, str(tmp_path))
    eng.submit(Request(ops=[("insert", 3, 4), ("read", 3)]))
    eng.run()                           # must not raise
    assert eng._profiling is False


# ---------------------------------------------------------------------------
# bounded engine telemetry (satellite: route_cap_log ring)
# ---------------------------------------------------------------------------

def test_route_cap_log_is_bounded():
    from repro.serving.engine import ROUTE_CAP_LOG_MAX
    eng = ServingEngine(num_shards=1, max_slots=2)
    for i in range(ROUTE_CAP_LOG_MAX + 50):
        eng._record_route_caps([1], [1], [1])
    assert len(eng.route_cap_log) == ROUTE_CAP_LOG_MAX
    assert eng.route_cap_totals["launches"] == ROUTE_CAP_LOG_MAX + 50
    assert len(eng.stats()["route_caps"]) == 8


def test_tenant_queue_service_split_accumulates():
    from repro.serving import TenantRegistry
    reg = TenantRegistry()
    t = reg.register("a")
    eng = ServingEngine(num_shards=1, max_slots=2, tenants=reg)
    for _ in range(3):
        eng.submit(Request(ops=[("insert", 1, 2), ("read", 1)], tenant=t))
    eng.run()
    assert t.stats["completed"] == 3
    assert t.stats["queue_secs"] >= 0.0
    assert t.stats["service_secs"] > 0.0
    snap = eng.metrics.snapshot()
    assert snap["service_ms"]["p50"] > 0.0


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
