"""End-to-end training integration: loss decreases, checkpoint resume is
bit-exact, failure injection restarts cleanly."""
import numpy as np
import pytest

import jax

from repro.configs import smoke_config
from repro.configs.base import OptimConfig, ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.train import train


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_loss_decreases(tmp_path, mesh):
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 128, 4, "train")
    oc = OptimConfig(lr=1e-3, warmup_steps=5, total_steps=25)
    _, _, losses, _, _ = train(cfg, shape, oc, mesh, num_steps=25,
                               ckpt_dir=str(tmp_path), ckpt_every=0,
                               verbose=False)
    first = np.mean([losses[s] for s in range(3)])
    last = np.mean([losses[s] for s in range(22, 25)])
    assert last < first - 0.3, (first, last)


def test_failure_restart_resumes_identically(tmp_path, mesh):
    cfg = smoke_config("qwen3-8b")
    shape = ShapeConfig("t", 64, 4, "train")
    oc = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=16)

    # uninterrupted run
    p_ref, _, losses_ref, _, _ = train(
        cfg, shape, oc, mesh, num_steps=16, ckpt_dir=str(tmp_path / "a"),
        ckpt_every=4, verbose=False)
    # interrupted at step 10, restarts from the step-8 checkpoint
    p_ft, _, losses_ft, _, pol = train(
        cfg, shape, oc, mesh, num_steps=16, ckpt_dir=str(tmp_path / "b"),
        ckpt_every=4, inject=[10], verbose=False)
    assert pol.restarts == 1
    # the replayed steps produce the identical trajectory (determinism)
    for s in (12, 15):
        assert abs(losses_ref[s] - losses_ft[s]) < 1e-5
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_ft)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_trains(tmp_path, mesh):
    cfg = smoke_config("llama3-8b")
    shape = ShapeConfig("t", 64, 4, "train")
    oc = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=12)
    _, _, losses, _, _ = train(cfg, shape, oc, mesh, num_steps=12,
                               ckpt_dir=str(tmp_path), ckpt_every=0,
                               grad_compression="bf16", verbose=False)
    assert losses[11] < losses[0]
