"""Bench-trajectory regression guard (make ci).

BENCH_kernels.json / BENCH_serving.json accumulate one run per PR (a
``runs`` list, benchmarks/bench_util.py).  This tool compares the NEWEST
run against the best of the LAST ``--window`` prior runs, metric by
metric, and fails (exit 1) on a >``--threshold``x regression — the
container is noisy, so the default bar is the ISSUE-5 1.5x, loose enough
to ignore jitter and tight enough to catch a real perf cliff landing in a
PR.

The windowed baseline fixes two failure modes of the old best-of-ALL-runs
scan: a one-off fluke run no longer ratchets the bar forever (it ages out
of the window), and a metric that appears for the FIRST time in the newest
run is reported as a visible ``NEW METRIC`` warning instead of being
skipped silently (it has no baseline; the next run will guard it).

Metric direction is inferred from the name: ``*us_per*`` / ``*ms*`` /
``*ns_per*`` / ``*calls_per_tick*`` are lower-better; ``*ops_per_sec`` /
``*speedup*`` are higher-better throughputs.  ``calls_per_tick`` guards
the fused-tick launch contract (a coalesced mesh tick is ONE shard_map
launch — a regression back to 3 trips the gate); ``route_cap`` fields are
workload-dependent telemetry, never guarded.  Rows are matched across runs
by their ``name`` field; run-level scalar metrics (e.g.
``speedup_coalesced_vs_per_request``) are compared too.

Usage:  python tools/bench_check.py [--threshold 1.5] [--window 5] [FILE ...]
        (default: both BENCH files that exist in the repo root)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_WINDOW = 5

LOWER_BETTER = ("us_per", "ms", "ns_per", "wall_seconds", "calls_per_tick",
                "rows_activated", "trace_overhead", "p99_growth_ratio")
HIGHER_BETTER = ("ops_per_sec", "speedup")
# wall-clock noise-dominated or workload-dependent fields we never guard
SKIP = ("request_latency", "tick_ms", "wall_seconds", "route_cap",
        "stall_events")
# eager / interpret-mode timings swing ~1.5x between runs on this container
# (see CHANGES.md PR 2: "3.7-5.5 us/elem across runs on this noisy
# container"); they get 2x the band so the guard trips on cliffs, not noise.
# Serving throughput/speedup rows are in the same class: a drain is a dozen
# ticks of wall clock (tens of ms even best-of-N), and an A/B of identical
# code across container sessions swings them 1.5-2x — ``calls_per_tick``
# (the fused launch-count contract) deliberately stays on the tight band.
NOISY = ("vec_us_per_elem", "scan_us_per_elem", "us_per_probe", "grow_ms",
         "ns_per_live_entry", "ops_per_sec", "serving_speedup",
         "speedup_coalesced", "p99_growth_ratio")
NOISY_FACTOR = 2.0
# absolute (run-independent) ceilings, keyed by the metric's FIELD name
# (the part after the row prefix), all lower-better: ``trace_overhead`` is
# the traced/untraced ops-per-sec ratio from serving_bench — the ISSUE-9
# bar says enabling tracing may cost at most 10% throughput.
# ``p99_growth_ratio`` is the extendible/rebuild p99-under-growth latency
# ratio — the latency-bounded-growth acceptance bar: an extendible split
# must keep tail latency STRICTLY below the stop-the-world rebuild's (the
# 0.999 ceiling is "strictly below" with float headroom; in practice the
# ratio sits far under it).  Unlike the windowed relative check, these
# fire even on a metric's first appearance.
ABS_BARS = {"trace_overhead": 1.10, "p99_growth_ratio": 0.999}


def _direction(key: str):
    if any(s in key for s in SKIP):
        return None
    if any(s in key for s in HIGHER_BETTER):
        return "up"
    if any(s in key for s in LOWER_BETTER):
        return "down"
    return None


def _metrics(obj: dict, prefix: str):
    for k, v in obj.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            d = _direction(k)
            if d:
                yield f"{prefix}{k}", d, float(v)


def _run_metrics(run: dict) -> dict:
    out = {}
    for name, d, v in _metrics(run, ""):
        out[name] = (d, v)
    for row in run.get("rows", []):
        rn = row.get("name", "?")
        for name, d, v in _metrics(row, f"{rn}."):
            out[name] = (d, v)
    return out


def check_runs(runs: list, threshold: float,
               window: int = DEFAULT_WINDOW) -> tuple:
    """Newest run vs the best of the last ``window`` prior runs.  Returns
    (failures, warnings, compared): failures are (name, direction, best,
    newest, ratio); warnings are first-appearance metric names (present in
    the newest run, absent from EVERY prior run — no baseline yet)."""
    newest = _run_metrics(runs[-1])
    prior_all = [_run_metrics(r) for r in runs[:-1]]
    prior = prior_all[-window:] if window > 0 else prior_all
    failures, warnings = [], []
    compared = 0
    for name, (d, v) in newest.items():
        bar_abs = ABS_BARS.get(name.rsplit(".", 1)[-1])
        if bar_abs is not None and v > bar_abs:
            # absolute ceiling: direction "abs", "best" carries the bar
            failures.append((name, "abs", bar_abs, v, v / bar_abs))
        best = None
        for p in prior:
            if name in p and p[name][0] == d:
                pv = p[name][1]
                best = pv if best is None else (
                    max(best, pv) if d == "up" else min(best, pv))
        if not any(name in p for p in prior_all):
            warnings.append(name)
            continue
        if best is None or best <= 0 or v <= 0:
            continue
        compared += 1
        ratio = (best / v) if d == "up" else (v / best)
        bar = threshold * (NOISY_FACTOR if any(s in name for s in NOISY)
                           else 1.0)
        if ratio > bar:
            failures.append((name, d, best, v, ratio))
    return failures, warnings, compared


def check_file(path: str, threshold: float,
               window: int = DEFAULT_WINDOW) -> list:
    with open(path) as f:
        doc = json.load(f)
    runs = doc.get("runs", [])
    if len(runs) < 2:
        print(f"{path}: {len(runs)} run(s), nothing to compare")
        return []
    failures, warnings, compared = check_runs(runs, threshold, window)
    print(f"{path}: compared {compared} metrics, newest vs best of last "
          f"{min(window, len(runs) - 1)} of {len(runs) - 1} prior runs")
    for name in warnings:
        print(f"  NEW METRIC {name}: first appearance, no prior baseline "
              f"(guarded from the next run on)")
    for name, d, best, v, ratio in failures:
        if d == "abs":
            print(f"  ABS BAR {name}: {v:.4g} exceeds the hard ceiling "
                  f"{best:.4g} ({ratio:.2f}x over)")
            continue
        want = "higher" if d == "up" else "lower"
        print(f"  REGRESSION {name}: best prior {best:.4g}, "
              f"newest {v:.4g} ({ratio:.2f}x worse; {want}-is-better)")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="bench trajectory files (default: BENCH_*.json "
                         "next to the repo root)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when newest is this many times worse than "
                         "the best prior run in the window (default 1.5)")
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW,
                    help="compare against the best of the last K prior "
                         f"runs (default {DEFAULT_WINDOW}; 0 = all runs)")
    args = ap.parse_args()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = args.files or [
        p for p in (os.path.join(root, "BENCH_kernels.json"),
                    os.path.join(root, "BENCH_serving.json"))
        if os.path.exists(p)]
    if not files:
        print("no bench trajectory files found")
        return 0
    failures = []
    for path in files:
        failures += check_file(path, args.threshold, args.window)
    if failures:
        print(f"FAIL: {len(failures)} metric(s) regressed past "
              f"{args.threshold}x")
        return 1
    print("bench check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
