#!/usr/bin/env python
"""Repo lint entry point (``make lint``).

Prefers ``ruff check`` (config in pyproject.toml).  The container image does
not ship ruff and installing packages is off-limits, so when ruff is absent
this degrades to a dependency-free fallback that still catches the
high-signal subset: syntax errors and unused module-level imports.
"""
from __future__ import annotations

import ast
import shutil
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
TARGETS = ["src", "tests", "benchmarks", "examples", "tools"]


def try_ruff() -> int | None:
    """Run ruff if present; None when unavailable."""
    if shutil.which("ruff"):
        cmd = ["ruff"]
    else:
        probe = subprocess.run([sys.executable, "-m", "ruff", "--version"],
                               capture_output=True)
        if probe.returncode != 0:
            return None
        cmd = [sys.executable, "-m", "ruff"]
    return subprocess.run(cmd + ["check"] + TARGETS, cwd=ROOT).returncode


class _ImportUseVisitor(ast.NodeVisitor):
    """Collect module-level imported names and every name usage."""

    def __init__(self):
        self.imported: dict[str, int] = {}   # name -> lineno
        self.used: set[str] = set()

    def visit_Import(self, node):
        for a in node.names:
            name = (a.asname or a.name).split(".")[0]
            self.imported.setdefault(name, node.lineno)

    def visit_ImportFrom(self, node):
        if node.module == "__future__":
            return
        for a in node.names:
            if a.name == "*":
                continue
            self.imported.setdefault(a.asname or a.name, node.lineno)

    def visit_Name(self, node):
        self.used.add(node.id)


def fallback_lint() -> int:
    failures = 0
    for target in TARGETS:
        for path in sorted((ROOT / target).rglob("*.py")):
            rel = path.relative_to(ROOT)
            src = path.read_text()
            try:
                tree = ast.parse(src, filename=str(rel))
            except SyntaxError as e:
                print(f"{rel}:{e.lineno}: E999 syntax error: {e.msg}")
                failures += 1
                continue
            if path.name == "__init__.py":
                continue                     # re-export modules
            v = _ImportUseVisitor()
            v.visit(tree)
            exported = set()
            for node in tree.body:           # names re-exported via __all__
                if (isinstance(node, ast.Assign)
                        and any(isinstance(t, ast.Name) and t.id == "__all__"
                                for t in node.targets)
                        and isinstance(node.value, (ast.List, ast.Tuple))):
                    exported = {c.value for c in node.value.elts
                                if isinstance(c, ast.Constant)}
            for name, lineno in sorted(v.imported.items(),
                                       key=lambda kv: kv[1]):
                if name not in v.used and name not in exported:
                    print(f"{rel}:{lineno}: F401 '{name}' imported but unused")
                    failures += 1
    if failures:
        print(f"fallback lint: {failures} finding(s)")
    else:
        print("fallback lint: clean")
    return 1 if failures else 0


def main() -> int:
    rc = try_ruff()
    if rc is not None:
        return rc
    print("ruff not installed; running dependency-free fallback "
          "(syntax + unused module-level imports)")
    return fallback_lint()


if __name__ == "__main__":
    sys.exit(main())
