"""Offline analysis/validation of a ServingEngine Perfetto trace.

Loads the Chrome trace-event JSON written by ``ServingEngine.export_trace``
(or ``Tracer.export``), validates the event stream — every ``B`` has a
matching same-name ``E`` on its track, timestamps are monotonic per
(pid, tid) track, metadata ``M`` events are ignored — and prints:

  * the **per-phase time breakdown** (total/mean/max duration per span
    name, plus share of the summed tick wall time);
  * the **stall count** (``pipeline_stall`` spans + ``write_fence``
    instants) and total stalled time;
  * the **slowest-tick attribution table**: for the top-N slowest ``tick``
    spans, where the time went (phase spans nested in that tick's window
    on its lane).

Exit status is non-zero on a malformed trace or a failed ``--assert-*``
check, so ``make trace-smoke`` can gate CI on trace correctness:

    python tools/trace_report.py /tmp/trace.json \
        --assert-spans tick,gather,writeback --assert-stalls 1

``--assert-spans`` takes a comma-separated list of span names that must
appear (default: none); ``--assert-stalls N`` requires at least N
pipeline stalls (use on a read-your-writes workload where the write-claim
fence must fire).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

# engine span vocabulary (tracing.SPAN_NAMES), used for breakdown ordering
PHASE_ORDER = ("gather", "route", "probe", "delete", "insert", "fused_tick",
               "writeback", "pipeline_stall", "admit", "sample", "grow",
               "split", "compact", "preload")


def load_events(path: str) -> tuple:
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    other = doc.get("otherData", {}) if isinstance(doc, dict) else {}
    return events, other


def validate(events: list) -> tuple:
    """Check B/E balance + per-track monotonicity; returns
    (spans, instants, problems) where spans are completed
    (name, tid, ts, dur, args) tuples reconstructed from the B/E stream."""
    problems: list = []
    last_ts: dict = {}
    stacks: dict = defaultdict(list)
    spans: list = []
    instants: list = []
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            continue
        track = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts")
        if ph in ("B", "E"):
            if ts < last_ts.get(track, float("-inf")):
                problems.append(
                    f"non-monotonic ts on track {track}: {ts} after "
                    f"{last_ts[track]} ({ev.get('name')})")
            last_ts[track] = ts
            if ph == "B":
                stacks[track].append(ev)
            elif not stacks[track]:
                problems.append(
                    f"unmatched E {ev.get('name')!r} on track {track}")
            else:
                b = stacks[track].pop()
                if b["name"] != ev["name"]:
                    problems.append(
                        f"interleaved B/E on track {track}: opened "
                        f"{b['name']!r}, closed {ev['name']!r}")
                spans.append((b["name"], track[1], b["ts"],
                              ts - b["ts"], b.get("args", {})))
        elif ph == "i":
            instants.append((ev.get("name"), track[1], ts,
                             ev.get("args", {})))
    for track, stack in stacks.items():
        if stack:
            problems.append(f"{len(stack)} unclosed B event(s) on track "
                            f"{track}: {[b['name'] for b in stack]}")
    return spans, instants, problems


def phase_breakdown(spans: list) -> dict:
    """name -> {count, total_us, mean_us, max_us} over duration spans."""
    acc: dict = defaultdict(lambda: {"count": 0, "total_us": 0.0,
                                     "max_us": 0.0})
    for name, _, _, dur, _ in spans:
        a = acc[name]
        a["count"] += 1
        a["total_us"] += dur
        if dur > a["max_us"]:
            a["max_us"] = dur
    for a in acc.values():
        a["mean_us"] = a["total_us"] / a["count"]
    return dict(acc)


def slowest_ticks(spans: list, top: int = 5) -> list:
    """Top-N slowest tick spans, each with its nested-phase attribution:
    phase spans on the SAME lane whose interval falls inside the tick's.
    Returns [(tick_id, lane, dur_us, {phase: us})] slowest first."""
    ticks = [s for s in spans if s[0] == "tick"]
    ticks.sort(key=lambda s: -s[3])
    out = []
    for name, lane, ts, dur, args in ticks[:top]:
        inside: dict = defaultdict(float)
        for n2, l2, ts2, d2, _ in spans:
            if n2 != "tick" and l2 == lane and ts2 >= ts \
                    and ts2 + d2 <= ts + dur + 1e-3:
                inside[n2] += d2
        out.append((args.get("tick", "?"), lane, dur, dict(inside)))
    return out


def report(path: str, top: int = 5) -> tuple:
    events, other = load_events(path)
    spans, instants, problems = validate(events)
    print(f"{path}: {len(events)} events, {len(spans)} spans, "
          f"{len(instants)} instants"
          + (f", {other.get('dropped', 0)} ring drops" if other else ""))
    for p in problems:
        print(f"  INVALID: {p}")

    by_phase = phase_breakdown(spans)
    tick_total = by_phase.get("tick", {}).get("total_us", 0.0)
    print("\nper-phase breakdown (sum over spans):")
    order = [n for n in PHASE_ORDER if n in by_phase] + \
        sorted(set(by_phase) - set(PHASE_ORDER) - {"tick"})
    for name in ["tick"] * ("tick" in by_phase) + order:
        a = by_phase[name]
        share = f"  {100.0 * a['total_us'] / tick_total:5.1f}% of tick" \
            if tick_total and name != "tick" else ""
        print(f"  {name:<16} n={a['count']:<6} total={a['total_us']:.0f}us "
              f"mean={a['mean_us']:.1f}us max={a['max_us']:.1f}us{share}")

    stall_spans = by_phase.get("pipeline_stall", {"count": 0,
                                                  "total_us": 0.0})
    fences = sum(1 for n, _, _, _ in instants if n == "write_fence")
    kills = sum(1 for n, _, _, _ in instants if n == "kill")
    print(f"\nstalls: {stall_spans['count']} pipeline_stall span(s) "
          f"({stall_spans['total_us']:.0f}us total), {fences} write_fence "
          f"instant(s), {kills} kill(s)")

    slow = slowest_ticks(spans, top)
    if slow:
        print(f"\nslowest {len(slow)} tick(s):")
        for tick_id, lane, dur, inside in slow:
            attr = ", ".join(f"{n}={us:.0f}us" for n, us in
                             sorted(inside.items(), key=lambda kv: -kv[1]))
            other_us = dur - sum(inside.values())
            print(f"  tick {tick_id} (lane {lane}): {dur:.0f}us — {attr}"
                  f", unattributed={other_us:.0f}us")
    return spans, instants, problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate + summarize a ServingEngine Perfetto trace")
    ap.add_argument("trace", help="trace-event JSON file "
                    "(ServingEngine.export_trace output)")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest ticks to attribute (default 5)")
    ap.add_argument("--assert-spans", default="",
                    help="comma-separated span names that must appear")
    ap.add_argument("--forbid-spans", default="",
                    help="comma-separated span names that must NOT appear "
                         "(grow-smoke forbids 'grow' and 'pipeline_stall': "
                         "an extendible split must repair inline, neither "
                         "rebuilding the table nor flushing the pipeline)")
    ap.add_argument("--assert-stalls", type=int, default=0,
                    help="minimum pipeline_stall span count")
    args = ap.parse_args(argv)

    spans, instants, problems = report(args.trace, args.top)
    ok = not problems
    seen = {s[0] for s in spans}
    for want in filter(None, args.assert_spans.split(",")):
        if want.strip() not in seen:
            print(f"ASSERT FAILED: span {want.strip()!r} not in trace "
                  f"(saw {sorted(seen)})")
            ok = False
    for bad in filter(None, args.forbid_spans.split(",")):
        if bad.strip() in seen:
            print(f"ASSERT FAILED: forbidden span {bad.strip()!r} appears "
                  f"in trace")
            ok = False
    stalls = sum(1 for s in spans if s[0] == "pipeline_stall")
    if stalls < args.assert_stalls:
        print(f"ASSERT FAILED: {stalls} pipeline_stall span(s) < required "
              f"{args.assert_stalls}")
        ok = False
    print("\ntrace OK" if ok else "\ntrace FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
